"""Device-resident MD engine over the quantized sparse forward.

The deployment claim behind the paper's Fig. 3 — "stable, energy-
conserving MD for nanosecond timescales" on a quantized model — is a
throughput claim at heart: an MD run is 10^4-10^6 force calls, so any
per-step host work (neighbour-list rebuilds in Python, numpy round-trips
of forces, dispatch overhead) multiplies into the wall clock. This
module keeps the whole integration loop on device:

* **velocity-Verlet inside ``lax.scan``** — one compiled program
  integrates ``record_every`` steps per record; the host sees data only
  at record checkpoints (and once at the end of ``run``).
* **Verlet-skin neighbour lists** (``md/neighbor.py``) — the edge list
  is built at ``cutoff + skin`` and rebuilt on device under ``lax.cond``
  only when some atom has moved further than ``skin / 2``; before every
  force call the mask is refined back to the true cutoff
  (``kernels.ops.refine_edge_mask``), so forces are *exactly* those of a
  fresh list every step. Capacity overflow sets a sticky flag checked at
  the end of each ``run`` instead of syncing per step.
* **quantized sparse forward** — forces come from
  ``serving.forward.sparse_energy_and_forces``: the O(E) edge-list path
  through the fused W8A8/W4A8 matmul kernels, differentiated via their
  straight-through VJPs. The per-step energy is the same forward's value
  output, so recording total energy costs nothing extra.
* **batched replicas** — state is a padded ``(B, cap, ...)`` bucket of
  molecules integrated simultaneously through the batched forward,
  amortizing kernel launches across replicas; padded atoms have exactly
  zero force and never move.

``benchmarks/md_bench.py`` measures this against the legacy per-step
host loop and writes ``BENCH_md.json``; see docs/md.md for the
architecture notes and the skin heuristic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_codebook
from repro.guardrails import GuardrailViolation, check_finite_tree
from repro.kernels import ops
from repro.md.neighbor import NeighborList, build_neighbor_list, maybe_rebuild
from repro.md.nve import _FS
from repro.obs.metrics import REGISTRY
from repro.models import so3krates as so3
from repro.serving.bucketing import EDGE_LANE, count_edges
from repro.serving.forward import sparse_energy_and_forces
from repro.serving.qparams import QuantizedParams, quantize_so3_params

__all__ = ["MDConfig", "ReplicaState", "MDEngine", "pad_replicas"]

_KB = 8.617333e-5  # eV / K


@dataclasses.dataclass(frozen=True)
class MDConfig:
    """MD-side knobs, orthogonal to the model architecture config."""
    mode: str = "w8a8"               # "fp32" | "w8a8" | "w4a8"
    dt_fs: float = 0.5               # integration step, femtoseconds
    # skin radius (Angstrom): the edge list is built at cutoff + skin and
    # stays valid until some atom moves skin/2. Larger skin = fewer
    # rebuilds but more edge slots (every per-edge op pays for the
    # extras); 0 degenerates to rebuild-every-step. 0.45 balances the
    # two on the measured CPU profile (see BENCH_md.json).
    skin: float = 0.45
    record_every: int = 50           # steps between energy records
    # per-molecule edge slots for the skin list; None = sized at
    # init_state from the initial configuration's cutoff+skin edge count
    # times the safety factor, rounded up to EDGE_LANE
    edge_capacity: Optional[int] = None
    edge_capacity_safety: float = 1.3
    # MDDQ on l=1 features; None = follow the mode (on for quantized)
    quant_vectors: Optional[bool] = None
    # route matmuls through the Pallas kernels; None = auto (kernels on
    # TPU, the integer-jnp ref path on CPU — identical forward values,
    # same STE backward; the interpreter has nothing to fuse *for* on
    # CPU, same rule edge_kernel=None applies to the segment softmax)
    use_kernels: Optional[bool] = None
    # fused segment-softmax kernel; None = auto (TPU only)
    edge_kernel: Optional[bool] = None
    # serve-time MDDQ through the Pallas encode kernel
    mddq_kernel: bool = False
    # verification mode: count cutoff edges missed by the skin list every
    # step (O(cap^2) extra work — tests/benchmark audits only)
    track_missed: bool = False
    # -- runtime guardrails (checked at each record checkpoint, where
    # run() syncs to the host anyway — zero extra device work) --
    # raise a typed GuardrailViolation when a checkpoint's energies go
    # non-finite (an exploded trajectory is garbage from that point on)
    check_finite: bool = True
    # max admissible |e_tot - e_tot(first checkpoint)| per replica (eV);
    # None = drift monitor off. An NVE integrator at a sane dt conserves
    # e_tot — sustained drift is the quantized forward leaving its trust
    # region, the signal the session layer escalates a precision tier on
    drift_limit: Optional[float] = None

    def __post_init__(self):
        if self.mode not in ("fp32", "w8a8", "w4a8"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.skin < 0:
            raise ValueError("skin must be >= 0")
        if self.drift_limit is not None and self.drift_limit <= 0:
            raise ValueError("drift_limit must be > 0 or None")

    @property
    def vectors_quantized(self) -> bool:
        if self.quant_vectors is None:
            return self.mode != "fp32"
        return self.quant_vectors


class ReplicaState(NamedTuple):
    """Integration state for a padded batch of replicas. Everything a
    step touches lives here so ``lax.scan`` carries it on device."""
    coords: jnp.ndarray      # (B, cap, 3) Angstrom
    veloc: jnp.ndarray       # (B, cap, 3) A / t*
    forces: jnp.ndarray      # (B, cap, 3) eV / A
    e_pot: jnp.ndarray       # (B,) potential energy at coords
    nlist: NeighborList      # skin edge list + rebuild bookkeeping
    missed: jnp.ndarray      # () int32, cumulative missed cutoff edges
    #                          (only advanced when MDConfig.track_missed)


def pad_replicas(species: np.ndarray, coords: np.ndarray, n_replicas: int,
                 capacity: Optional[int] = None):
    """Tile one molecule into a padded replica batch.

    species (n,), coords (n, 3) -> (species (B, cap) int32,
    coords (B, cap, 3) f32, mask (B, cap) bool) with B = n_replicas and
    cap = capacity (default n). Replicas start identical; distinct
    initial velocities come from ``MDEngine.init_state``'s RNG.
    """
    n = int(species.shape[0])
    cap = n if capacity is None else capacity
    if cap < n:
        raise ValueError(f"capacity {cap} < molecule size {n}")
    sp = np.zeros((n_replicas, cap), np.int32)
    co = np.zeros((n_replicas, cap, 3), np.float32)
    mask = np.zeros((n_replicas, cap), bool)
    sp[:, :n] = np.asarray(species, np.int32)
    co[:, :n] = np.asarray(coords, np.float32)
    mask[:, :n] = True
    return sp, co, mask


class MDEngine:
    """Batched, device-resident NVE integrator for the quantized model."""

    def __init__(self, model_cfg: so3.So3kratesConfig,
                 params: Optional[Dict[str, jnp.ndarray]] = None,
                 md: MDConfig = MDConfig(),
                 qparams: Optional[QuantizedParams] = None,
                 codebook: Optional[jnp.ndarray] = None, seed: int = 0):
        """Build from trained fp32 ``params`` (quantized here per
        ``md.mode``) or from pre-quantized ``qparams`` (e.g. shared with
        a ``QuantizedEngine`` via ``engine.md_engine()``)."""
        self.model_cfg = model_cfg
        self.md = md
        if qparams is None:
            if params is None:
                params = so3.init_params(jax.random.PRNGKey(seed), model_cfg)
            qparams = quantize_so3_params(params, md.mode)
        self.qparams = qparams
        self._quant_vec = md.vectors_quantized
        if codebook is None and self._quant_vec:
            codebook = make_codebook(model_cfg.dir_bits)
        self._codebook = codebook
        if md.use_kernels is None:
            self._use_kernels = (md.mode != "fp32"
                                 and jax.default_backend() == "tpu")
        else:
            self._use_kernels = md.use_kernels
        # one compiled program per segment length: run() dispatches
        # n_records identical record_every-step segments (plus at most
        # one remainder segment), so total step count never recompiles.
        # Donation lets XLA reuse the state buffers across segments; the
        # caller's own input state is protected by a device copy in
        # run(), not by a second (donation-free) compilation of the
        # segment program. CPU does not support donation and would warn
        # on every call.
        self._donate = jax.default_backend() != "cpu"
        self._segment_jit = jax.jit(
            self._segment_impl, static_argnames=("length",),
            donate_argnums=(0,) if self._donate else ())

    # -- forces --------------------------------------------------------------

    def _energy_forces(self, species, coords, mask, nlist: NeighborList):
        """Quantized sparse forward at the true cutoff: the skin list's
        mask is refined to d < cutoff at the current coordinates (fused
        into the forward's geometry pass via ``refine_cutoff``), so the
        edge set equals a fresh rebuild's exactly."""
        return sparse_energy_and_forces(
            self.qparams, self.model_cfg, species, coords, mask,
            nlist.senders, nlist.receivers, nlist.edge_mask,
            self._codebook, quant_vectors=self._quant_vec,
            use_kernels=self._use_kernels,
            edge_kernel=self.md.edge_kernel,
            mddq_kernel=self.md.mddq_kernel, refine_cutoff=True)

    def _count_missed(self, coords, mask, nlist: NeighborList):
        """Cutoff edges absent from the refined skin list (must be 0 —
        the conservativeness audit behind MDConfig.track_missed)."""
        B, cap = mask.shape
        cutoff = self.model_cfg.cutoff
        rij = coords[:, :, None, :] - coords[:, None, :, :]
        d2 = jnp.sum(rij * rij, axis=-1)
        fresh = ((d2 < cutoff * cutoff) & ~jnp.eye(cap, dtype=bool)[None]
                 & mask[:, :, None] & mask[:, None, :])
        em = ops.refine_edge_mask(coords.reshape(-1, 3), nlist.senders,
                                  nlist.receivers, nlist.edge_mask, cutoff)
        b = nlist.receivers // cap
        have = jnp.zeros((B, cap, cap), jnp.int32).at[
            b, nlist.receivers % cap, nlist.senders % cap
        ].add(em.astype(jnp.int32)) > 0
        return jnp.sum(fresh & ~have).astype(jnp.int32)

    # -- integration ---------------------------------------------------------

    def _step(self, s: ReplicaState, species, mask, inv_m, dt):
        v_half = s.veloc + 0.5 * dt * s.forces * inv_m
        r_new = s.coords + dt * v_half
        # rebuild BEFORE the force call: while max displacement stays
        # under skin/2 the old list is provably conservative, and the
        # moment it is not, the list is rebuilt at these coordinates
        nlist = maybe_rebuild(s.nlist, r_new, mask, self.model_cfg.cutoff,
                              self.md.skin)
        e_pot, f_new = self._energy_forces(species, r_new, mask, nlist)
        v_new = v_half + 0.5 * dt * f_new * inv_m
        missed = s.missed
        if self.md.track_missed:
            missed = missed + self._count_missed(r_new, mask, nlist)
        return ReplicaState(r_new, v_new, f_new, e_pot, nlist, missed)

    def _segment_impl(self, state: ReplicaState, species, mask, masses,
                      length: int):
        """``length`` velocity-Verlet steps in one device program,
        returning the state plus one energy/temperature record."""
        dt = self.md.dt_fs * _FS
        inv_m = jnp.where(mask, 1.0 / jnp.maximum(masses, 1e-9),
                          0.0)[..., None]

        def one_step(s, _):
            return self._step(s, species, mask, inv_m, dt), None

        state, _ = jax.lax.scan(one_step, state, None, length=length)
        m_eff = jnp.where(mask, masses, 0.0)
        e_kin = 0.5 * jnp.sum(m_eff[..., None] * state.veloc ** 2,
                              axis=(1, 2))
        # 3N - 3 degrees of freedom: init_state removes the per-replica
        # centre-of-mass momentum and NVE conserves it at zero
        n_dof = jnp.maximum(3.0 * mask.sum(-1).astype(jnp.float32) - 3.0,
                            1.0)
        rec = {"e_pot": state.e_pot, "e_tot": state.e_pot + e_kin,
               "temperature_K": 2.0 * e_kin / (n_dof * _KB)}
        return state, rec

    # -- public API ----------------------------------------------------------

    def init_state(self, key: jax.Array, species, coords, mask, masses,
                   temperature_K: float = 300.0,
                   edge_capacity: Optional[int] = None) -> ReplicaState:
        """Maxwell-Boltzmann initialization of a padded replica batch.

        species (B, cap) int32, coords (B, cap, 3), mask (B, cap) bool,
        masses (cap,) or (B, cap) amu (padded entries may hold any
        positive value — padded atoms never move). Sizes the skin list's
        edge capacity from this configuration unless given, builds it,
        and evaluates initial forces. Raises if the initial cutoff+skin
        graph overflows the capacity.
        """
        species = jnp.asarray(species, jnp.int32)
        coords = jnp.asarray(coords, jnp.float32)
        mask = jnp.asarray(mask, bool)
        masses = jnp.broadcast_to(jnp.asarray(masses, jnp.float32),
                                  mask.shape)
        B, cap = mask.shape

        ec = self.md.edge_capacity if edge_capacity is None else edge_capacity
        if ec is None:
            counts = count_edges(np.asarray(coords), np.asarray(mask),
                                 self.model_cfg.cutoff + self.md.skin)
            ec = int(counts.max()) * self.md.edge_capacity_safety
            ec = -(-max(int(ec), 1) // EDGE_LANE) * EDGE_LANE
            ec = min(ec, -(-cap * cap // EDGE_LANE) * EDGE_LANE)
        if ec % EDGE_LANE != 0:
            raise ValueError(
                f"edge_capacity {ec} is not a multiple of {EDGE_LANE}")

        nlist = build_neighbor_list(coords, mask, self.model_cfg.cutoff,
                                    self.md.skin, ec)
        if bool(nlist.overflow):
            raise ValueError(
                f"initial cutoff+skin graph overflows edge_capacity={ec}; "
                "raise MDConfig.edge_capacity or edge_capacity_safety")

        std = jnp.sqrt(_KB * temperature_K
                       / jnp.maximum(masses, 1e-9))[..., None]
        v = jax.random.normal(key, coords.shape) * std * mask[..., None]
        # remove per-replica centre-of-mass drift over real atoms
        m = (masses * mask)[..., None]
        p = jnp.sum(m * v, axis=1, keepdims=True) \
            / jnp.maximum(jnp.sum(m, axis=1, keepdims=True), 1e-9)
        v = (v - p) * mask[..., None]

        e_pot, forces = self._energy_forces(species, coords, mask, nlist)
        return ReplicaState(coords=coords, veloc=v, forces=forces,
                            e_pot=e_pot, nlist=nlist,
                            missed=jnp.zeros((), jnp.int32))

    def run(self, state: ReplicaState, species, mask, masses,
            n_steps: int, record_every: Optional[int] = None
            ) -> Tuple[ReplicaState, Dict[str, np.ndarray]]:
        """Integrate ``n_steps`` of NVE, one device dispatch per record.

        Each ``record_every``-step segment is a single compiled scan —
        the host syncs only at record checkpoints (where it also checks
        the overflow flag, raising if an on-device skin rebuild exceeded
        the edge capacity — the trajectory is invalid past that point).
        Returns the final state and a record dict: ``e_pot`` / ``e_tot``
        / ``temperature_K`` arrays of shape ``(n_records, B)`` sampled
        every ``record_every`` steps (one extra, shorter-interval sample
        covers any remainder — no steps are dropped), plus scalar
        ``n_rebuilds`` and ``missed_edges`` counters.
        """
        if record_every is None:
            record_every = self.md.record_every
        species = jnp.asarray(species, jnp.int32)
        mask = jnp.asarray(mask, bool)
        masses = jnp.broadcast_to(jnp.asarray(masses, jnp.float32),
                                  mask.shape)
        if self._donate:
            # the first segment would otherwise donate the caller's
            # buffers (e.g. an init_state kept around to restart)
            state = jax.tree_util.tree_map(jnp.copy, state)
        n_records, tail = divmod(n_steps, record_every)
        lengths = [record_every] * n_records + ([tail] if tail else [])
        recs = []
        e_ref: Optional[np.ndarray] = None   # first checkpoint's e_tot
        for length in lengths:
            state, rec = self._segment_jit(state, species, mask, masses,
                                           length=length)
            if bool(state.nlist.overflow):   # the per-checkpoint host sync
                raise RuntimeError(
                    "skin neighbour list overflowed its edge capacity "
                    f"({state.nlist.edge_capacity}) during the run; raise "
                    "MDConfig.edge_capacity / edge_capacity_safety")
            # guardrails ride the same host sync: non-finite energies and
            # (when armed) per-replica e_tot drift vs the first checkpoint
            if self.md.check_finite or self.md.drift_limit is not None:
                e_tot = np.asarray(rec["e_tot"])
                if self.md.check_finite:
                    bad = check_finite_tree(
                        {"e_tot": e_tot, "e_pot": np.asarray(rec["e_pot"])})
                    if bad is not None:
                        raise GuardrailViolation(
                            f"non-finite {bad} at an MD checkpoint (mode "
                            f"{self.md.mode}) — the trajectory exploded",
                            reason="nonfinite", severity="fatal",
                            detail={"mode": self.md.mode, "array": bad})
                if self.md.drift_limit is not None:
                    if e_ref is None:
                        e_ref = e_tot
                    else:
                        drift = float(np.abs(e_tot - e_ref).max())
                        # SLO feed: drift as a fraction of the limit
                        # (> 1.0 breaches md_energy_drift) — published
                        # whether or not the guardrail trips, so the
                        # health plane sees drift *approaching* the
                        # limit too
                        REGISTRY.gauge(
                            "md_energy_drift_ratio",
                            mode=self.md.mode).set(
                            drift / self.md.drift_limit)
                        if drift > self.md.drift_limit:
                            raise GuardrailViolation(
                                f"energy drift {drift:.4g} eV exceeds "
                                f"drift_limit={self.md.drift_limit} eV "
                                f"(mode {self.md.mode})",
                                reason="energy_drift", severity="suspect",
                                detail={"mode": self.md.mode,
                                        "value": drift,
                                        "limit": self.md.drift_limit})
            recs.append(rec)
        records = {k: np.stack([np.asarray(r[k]) for r in recs])
                   for k in recs[0]} if recs else {}
        records["n_rebuilds"] = int(state.nlist.n_rebuilds)
        records["missed_edges"] = int(state.missed)
        return state, records

    # -- introspection -------------------------------------------------------

    @property
    def backend(self) -> str:
        return jax.default_backend()
