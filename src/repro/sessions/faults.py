"""Seeded fault-injection harness for streaming MD sessions.

Chaos testing only proves something when the faults are (a) the real
failure modes and (b) reproducible. This module schedules four of them,
from one seed, against a live :class:`~repro.cluster.pool.ClusterPool`
and a session's on-disk checkpoints:

* ``kill_replica`` — ``ClusterPool.kill_replica(mode="drain"|"in_flight")``:
  the replica dies with queued (and, in-flight mode, already-picked)
  work, exercising orphan requeue + the session's chunk retry;
* ``swap_artifact`` — a mid-trajectory rolling weight swap: the session
  must keep integrating across the artifact-version boundary (frames
  carry the version so the splice point is auditable);
* ``corrupt_checkpoint`` — flip one byte (``bitflip``) or cut the file
  in half (``truncate``) in the *newest* checkpoint step on disk: a
  later restore must detect it (per-array SHA-256 →
  :class:`~repro.checkpoint.manager.CheckpointError`) and fall back to
  the previous valid step;
* ``stall`` — ``Replica.inject_stall``: the next flush/chunk holds the
  engine lock ``stall_s`` seconds — the slow-straggler mode that delays
  without killing.

Faults fire at **chunk boundaries** of the session that owns the
injector (the driver thread calls :meth:`FaultInjector.fire` before
submitting each chunk), which makes a schedule a plain list of
``(kind, at_chunk)`` pairs — deterministic given the seed, independent
of wall clock. ``seeded_schedule`` draws one from ``numpy.random``.
"""
from __future__ import annotations

import dataclasses
import os
import re
import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["FaultSpec", "FaultInjector", "seeded_schedule",
           "corrupt_checkpoint"]

KINDS = ("kill_replica", "swap_artifact", "corrupt_checkpoint", "stall")

_STEP_RE = re.compile(r"^step_(\d+)$")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault. ``at_chunk`` is the session chunk index
    *before* which it fires (fault at the boundary, then the chunk runs
    into it)."""
    kind: str
    at_chunk: int
    # target pool replica; -1 = the replica that ran the session's last
    # chunk (the sticky one — guarantees the fault lands on the
    # session's own path rather than an idle bystander)
    replica_id: int = -1
    mode: str = "drain"             # kill_replica: "drain" | "in_flight"
    artifact_path: str = ""         # swap_artifact: packed artifact
    swap_warmup: bool = True        # swap_artifact: warm before exchange
    corruption: str = "bitflip"     # corrupt_checkpoint: | "truncate"
    stall_s: float = 0.2            # stall duration

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


def corrupt_checkpoint(checkpoint_dir: str, corruption: str = "bitflip",
                       seed: int = 0) -> Optional[str]:
    """Damage the newest ``step_N`` directory under ``checkpoint_dir``:
    flip one byte of one array file, or truncate it to half. Returns the
    damaged file's path (None when there is no checkpoint yet — a
    schedule may fire before the first save; the injector counts it as
    a no-op). The point is what happens *later*: ``latest_step()`` must
    skip the damaged step and restore must fall back."""
    if not os.path.isdir(checkpoint_dir):
        return None
    steps = sorted(int(m.group(1)) for m in
                   (_STEP_RE.match(n) for n in os.listdir(checkpoint_dir))
                   if m)
    if not steps:
        return None
    d = os.path.join(checkpoint_dir, f"step_{steps[-1]}")
    npys = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    if not npys:
        return None
    rng = np.random.default_rng(seed)
    target = os.path.join(d, npys[int(rng.integers(len(npys)))])
    size = os.path.getsize(target)
    if corruption == "truncate":
        with open(target, "r+b") as f:
            f.truncate(max(size // 2, 1))
    elif corruption == "bitflip":
        off = int(rng.integers(size))
        with open(target, "r+b") as f:
            f.seek(off)
            b = f.read(1)
            f.seek(off)
            f.write(bytes([b[0] ^ 0x40]))
    else:
        raise ValueError(f"unknown corruption {corruption!r}")
    return target


def seeded_schedule(seed: int, n_chunks: int, n_replicas: int,
                    kinds: Sequence[str] = KINDS,
                    n_faults: int = 4) -> List[FaultSpec]:
    """Draw a reproducible fault schedule: ``n_faults`` faults at
    distinct chunk boundaries in ``[1, n_chunks)`` (never before chunk 0
    — a session must exist to be hurt), one of each requested kind
    first, then repeats. The same ``(seed, n_chunks, n_replicas)``
    always yields the same schedule — the property the chaos bench's
    regression gate rests on."""
    for k in kinds:
        if k not in KINDS:
            raise ValueError(f"unknown fault kind {k!r}")
    rng = np.random.default_rng(seed)
    hi = max(n_chunks, 2)
    boundaries = rng.choice(np.arange(1, hi), size=min(n_faults, hi - 1),
                            replace=False)
    specs = []
    for i, at in enumerate(sorted(int(b) for b in boundaries)):
        kind = kinds[i % len(kinds)]
        specs.append(FaultSpec(
            kind=kind, at_chunk=at,
            replica_id=int(rng.integers(n_replicas)),
            mode=("in_flight" if rng.integers(2) else "drain"),
            corruption=("truncate" if rng.integers(2) else "bitflip"),
            stall_s=float(0.05 + 0.2 * rng.random())))
    return specs


class FaultInjector:
    """Applies a :class:`FaultSpec` schedule to a live pool + session.

    The owning session's driver thread calls :meth:`fire` at every chunk
    boundary; each spec fires exactly once (the first boundary at or
    past its ``at_chunk`` — a resume that skips boundaries replays from
    an earlier chunk, so late firing keeps the schedule meaningful
    rather than silently dropping faults). ``counts()`` reports
    injected faults by kind for ``ClusterPool.stats()`` and the bench.
    """

    def __init__(self, schedule: Sequence[FaultSpec], pool,
                 seed: int = 0):
        self.schedule = list(schedule)
        self.pool = pool
        self.seed = seed
        self._fired = [False] * len(self.schedule)
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {k: 0 for k in KINDS}
        self._noop = 0

    def fire(self, session, chunk_idx: int) -> List[FaultSpec]:
        """Apply every not-yet-fired spec with ``at_chunk <= chunk_idx``.
        Returns the specs applied (tests assert on this)."""
        todo = []
        with self._lock:
            for i, spec in enumerate(self.schedule):
                if not self._fired[i] and spec.at_chunk <= chunk_idx:
                    self._fired[i] = True
                    todo.append(spec)
        applied = []
        for spec in todo:
            if self._apply(spec, session):
                with self._lock:
                    self._counts[spec.kind] += 1
                applied.append(spec)
            else:
                with self._lock:
                    self._noop += 1
        return applied

    def _target(self, spec: FaultSpec, session, live):
        rid = spec.replica_id
        if rid < 0:
            rid = getattr(session, "preferred_replica", None)
            if rid is None:
                rid = live[0].replica_id
        return next((r for r in live if r.replica_id == rid), live[0])

    def _apply(self, spec: FaultSpec, session) -> bool:
        if spec.kind == "kill_replica":
            live = [r for r in self.pool._replicas if r.accepting]
            if len(live) <= 1:
                return False     # never kill the last replica: that is
            #                      an outage, not a fault drill
            target = self._target(spec, session, live)
            self.pool.kill_replica(target.replica_id, mode=spec.mode)
            return True
        if spec.kind == "swap_artifact":
            self.pool.swap_artifact(spec.artifact_path,
                                    warmup=spec.swap_warmup)
            return True
        if spec.kind == "corrupt_checkpoint":
            return corrupt_checkpoint(
                session.checkpoint_dir, spec.corruption,
                seed=self.seed) is not None
        if spec.kind == "stall":
            live = [r for r in self.pool._replicas if r.accepting]
            if not live:
                return False
            self._target(spec, session, live).inject_stall(spec.stall_s)
            return True
        raise AssertionError(spec.kind)

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out = dict(self._counts)
            out["noop"] = self._noop
            out["total"] = sum(self._counts.values())
        return out
