"""Streaming MD sessions: long fault-tolerant trajectories served
through the cluster beside one-shot inference. See docs/sessions.md."""
from repro.sessions.faults import (FaultInjector, FaultSpec,
                                   corrupt_checkpoint, seeded_schedule)
from repro.sessions.manager import (Frame, MDSession, SessionConfig,
                                    SessionManager)

__all__ = ["Frame", "MDSession", "SessionConfig", "SessionManager",
           "FaultInjector", "FaultSpec", "corrupt_checkpoint",
           "seeded_schedule"]
