"""Streaming MD sessions over the cluster: chunked trajectories that
survive replica deaths, rolling weight swaps, and process restarts.

``repro.md`` runs closed trajectories; ``repro.cluster`` serves one-shot
inference. This module bridges them into the multi-tenant service the
paper's "nanosecond-timescale MD" claim actually needs: a
:class:`SessionManager` slices a long NVE trajectory into
**chunks** — each one ``MDEngine.run`` call of ``chunk_steps`` steps,
i.e. a handful of the engine's compiled ``lax.scan`` segments — and
submits them through :meth:`ClusterPool.submit_chunk` as
:class:`~repro.cluster.replica.ChunkHandle`\\ s, interleaved with
one-shot traffic under the existing admission/affinity policy. Completed
frames stream back through an iterator/callback API as each chunk
lands.

Why this survives faults:

* **state lives on the host between chunks.** Each chunk is a pure
  function of the session's host-side numpy state: ``device_put`` onto
  whichever replica runs it, integrate, ``device_get`` back. A chunk
  that dies with its replica (or is requeued by the pool's failover)
  is simply re-submitted from the same state — NVE integration has no
  per-step RNG (the only key is consumed at ``init_state``), so replay
  is bit-deterministic and retries are free of double-integration.
* **checkpoints every K chunks.** Session state (``ReplicaState``
  including the skin neighbour list, species/mask/masses, the init RNG
  key, step counter, artifact version) persists through
  :class:`~repro.checkpoint.manager.CheckpointManager` — atomic step
  dirs, per-array SHA-256. ``resume_all()`` scans the checkpoint root
  after a full process restart, takes each session's ``latest_step()``
  (digest verification makes a corrupted newest step fall back to the
  previous valid one), and replays the un-checkpointed tail
  deterministically.
* **typed retry-with-backoff.** A shed submission
  (:class:`SchedulerOverloaded`) backs off by the scheduler's
  ``retry_after_s`` hint; a failed chunk (:class:`ReplicaFailed`, a
  typed :class:`~repro.server.scheduler.RequestTimeout` from the chunk
  deadline, or an engine error) retries on the survivors with
  exponential backoff under **full jitter** — waits are drawn uniformly
  from ``[0, backoff]`` per session, so many sessions shed by the same
  overload burst don't retry in lockstep and re-shed together. Budget
  exhausted or pool closed → the session fails loudly with its error,
  never silently stalls.
* **guardrail tier escalation.** A chunk the MD guardrails reject
  (:class:`~repro.guardrails.GuardrailViolation`: non-finite energies,
  energy drift past ``MDConfig.drift_limit``) is re-submitted with
  ``min_tier`` one precision step above the mode that failed — the
  tiered pool routes it to a w8a8/fp32 escalation replica, and
  ``_md_engine_for`` integrates at *that* replica's precision. Bounded
  by ``SessionConfig.max_escalations``; past the ladder top the session
  fails with the violation (fp32 exploding is real physics, not
  quantization).

Delivery semantics: frames are **exactly-once within a process** (chunk
completion is monotonic on the driver thread) and **at-least-once
across restarts** — frames after the last checkpoint are re-emitted on
resume with identical indices and payloads (determinism), so consumers
dedupe by ``Frame.index``. ``chunk_steps`` is the latency/throughput
knob: long chunks amortize dispatch + host round-trips, short chunks
bound how long a one-shot flush waits behind MD work and how much is
replayed after a fault (see docs/sessions.md).
"""
from __future__ import annotations

import dataclasses
import math
import os
import queue
import re
import threading
import time
import weakref
from typing import Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointError, CheckpointManager
from repro.cluster.pool import ClusterPool
from repro.guardrails import GuardrailViolation, next_tier
from repro.md.engine import MDConfig, MDEngine, ReplicaState, pad_replicas
from repro.md.neighbor import NeighborList
from repro.obs.metrics import REGISTRY
from repro.server.scheduler import (RequestTimeout, SchedulerClosed,
                                    SchedulerOverloaded)
from repro.serving.bucketing import assign_bucket

__all__ = ["Frame", "SessionConfig", "MDSession", "SessionManager"]

_ID_RE = re.compile(r"[^A-Za-z0-9_.-]")


@dataclasses.dataclass(frozen=True)
class Frame:
    """One streamed trajectory record (one ``record_every`` boundary).
    ``index`` is the global record index — the dedupe key across
    restarts; ``step`` the MD step it samples. Per-replica arrays are
    shape ``(B,)`` for the session's replica batch."""
    session_id: str
    index: int
    step: int
    e_pot: np.ndarray
    e_tot: np.ndarray
    temperature_K: np.ndarray
    replica_id: int            # pool replica that integrated the chunk
    artifact_version: str      # weights the chunk ran under


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Session knobs. ``chunk_steps`` must be a multiple of
    ``record_every`` so global frame indices stay chunk-aligned (the
    last chunk may be shorter; its tail record covers the remainder)."""
    n_steps: int = 1000
    chunk_steps: int = 100          # MD steps per cluster chunk
    record_every: int = 50          # steps between streamed frames
    checkpoint_every: int = 4       # chunks between checkpoints (K)
    temperature_K: float = 300.0
    md: MDConfig = MDConfig()
    n_replicas: int = 1             # MD replica batch B (not pool replicas)
    max_retries: int = 12           # per-chunk retry budget (faults+sheds)
    backoff_s: float = 0.05         # initial retry backoff
    backoff_max_s: float = 2.0
    # per-chunk wall deadline: handle.result raises a typed
    # RequestTimeout past this, counting against the retry budget
    result_timeout_s: float = 600.0
    # precision-tier re-runs a guardrail-rejected chunk may receive
    # (GuardrailViolation from the MD engine -> re-submit with min_tier
    # one step up the ladder) before the session fails with it
    max_escalations: int = 1



    def __post_init__(self):
        if self.n_steps < 1 or self.chunk_steps < 1:
            raise ValueError("n_steps and chunk_steps must be >= 1")
        if self.record_every < 1:
            raise ValueError("record_every must be >= 1")
        if self.chunk_steps % self.record_every != 0:
            raise ValueError(
                f"chunk_steps {self.chunk_steps} must be a multiple of "
                f"record_every {self.record_every} (frame indices are "
                "chunk-aligned)")
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")

    @property
    def n_chunks(self) -> int:
        return math.ceil(self.n_steps / self.chunk_steps)

    @property
    def frames_per_chunk(self) -> int:
        return self.chunk_steps // self.record_every

    def chunk_len(self, chunk_idx: int) -> int:
        done = chunk_idx * self.chunk_steps
        return min(self.chunk_steps, self.n_steps - done)


_SENTINEL = object()


class MDSession:
    """One long-running trajectory: host-side state + frame stream +
    telemetry. Created by :meth:`SessionManager.start` /
    :meth:`SessionManager.resume_all`; driven by a manager thread."""

    def __init__(self, session_id: str, config: SessionConfig,
                 species: np.ndarray, mask: np.ndarray,
                 masses: np.ndarray, init_coords: np.ndarray,
                 bucket_capacity: int, seed: int, checkpoint_dir: str,
                 on_frame: Optional[Callable[[Frame], None]] = None,
                 retain_frames: bool = True,
                 state=None, chunks_done: int = 0, steps_done: int = 0):
        self.session_id = session_id
        self.config = config
        self.species = np.asarray(species, np.int32)
        self.mask = np.asarray(mask, bool)
        self.masses = np.asarray(masses, np.float32)
        self.init_coords = np.asarray(init_coords, np.float32)
        self.bucket_capacity = bucket_capacity
        self.seed = seed
        self.checkpoint_dir = checkpoint_dir
        self.on_frame = on_frame
        self.retain_frames = retain_frames
        self.state = state                  # host numpy ReplicaState tree
        self.chunks_done = chunks_done
        self.steps_done = steps_done
        self.status = "pending"             # running | done | failed | cancelled
        self.error: Optional[BaseException] = None
        self.preferred_replica: Optional[int] = None
        self.last_artifact_version = ""
        self.artifact_versions: List[str] = []   # distinct versions seen
        self.collected: List[Frame] = []    # retained frames (tests/bench)
        self.n_retries = 0
        self.n_escalations = 0              # guardrail tier escalations
        self.n_checkpoints = 0
        self.n_restores = 0
        self.frames_emitted = 0
        # full-jitter retry RNG: deterministic per session, distinct
        # across sessions so a shared overload burst doesn't make every
        # session retry (and re-shed) in lockstep
        self._rng = np.random.default_rng(
            [seed & 0x7FFFFFFF] + [ord(c) for c in session_id[:24]])
        self._frame_q: "queue.Queue" = queue.Queue()
        self._cancel = threading.Event()
        self._done = threading.Event()
        self._lock = threading.Lock()

    # -- client side --------------------------------------------------------

    def frames(self) -> Iterator[Frame]:
        """Stream frames as chunks complete; ends when the session does
        (single consumer — use ``on_frame`` to fan out)."""
        while True:
            f = self._frame_q.get()
            if f is _SENTINEL:
                return
            yield f

    def wait(self, timeout: Optional[float] = None) -> str:
        """Block until the session finishes; returns the final status.
        Raises the session's error if it failed."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"session {self.session_id} not finished in {timeout}s")
        if self.status == "failed" and self.error is not None:
            raise self.error
        return self.status

    def cancel(self) -> None:
        """Stop at the next chunk boundary (state already checkpointed
        chunks stay on disk — a later ``resume_all`` picks it back up)."""
        self._cancel.set()

    def done(self) -> bool:
        return self._done.is_set()

    # -- driver side --------------------------------------------------------

    def _deliver(self, frame: Frame) -> None:
        with self._lock:
            self.frames_emitted += 1
            if self.retain_frames:
                self.collected.append(frame)
        REGISTRY.counter("session_frames_total", event="emitted").inc()
        if self.on_frame is not None:
            self.on_frame(frame)
        self._frame_q.put(frame)

    def _finish(self, status: str, error: Optional[BaseException] = None):
        with self._lock:
            self.status = status
            self.error = error
        self._frame_q.put(_SENTINEL)
        self._done.set()

    def telemetry(self) -> Dict[str, object]:
        with self._lock:
            return {
                "session_id": self.session_id, "status": self.status,
                "chunks_done": self.chunks_done,
                "n_chunks": self.config.n_chunks,
                "steps_done": self.steps_done,
                "frames_emitted": self.frames_emitted,
                "n_retries": self.n_retries,
                "n_escalations": self.n_escalations,
                "n_checkpoints": self.n_checkpoints,
                "n_restores": self.n_restores,
                "artifact_versions": list(self.artifact_versions),
            }


class SessionManager:
    """Runs streaming MD sessions through a :class:`ClusterPool`.

    One driver thread per session submits chunks (sticky to the replica
    that ran the last one, falling back to JSQ), streams frames,
    checkpoints every ``checkpoint_every`` chunks, and retries through
    sheds and replica deaths. Attach a
    :class:`~repro.sessions.faults.FaultInjector` to fire a seeded
    chaos schedule at chunk boundaries. The manager registers its
    telemetry as the ``sessions`` section of ``pool.stats()``.
    """

    def __init__(self, pool: ClusterPool, checkpoint_root: str,
                 faults=None, keep: int = 3):
        self.pool = pool
        self.root = checkpoint_root
        self.faults = faults
        self.keep = keep
        os.makedirs(checkpoint_root, exist_ok=True)
        self._sessions: Dict[str, MDSession] = {}
        self._threads: Dict[str, threading.Thread] = {}
        self._lock = threading.Lock()
        self._md_cache = weakref.WeakKeyDictionary()  # engine -> {md: MDEngine}
        self._md_lock = threading.Lock()
        self._n_seq = 0
        self._chunks_completed = 0
        self._chunks_retried = 0
        self._shed_retries = 0
        self._chunk_timeouts = 0        # typed RequestTimeout on result()
        self._chunk_escalations = 0     # guardrail tier re-runs
        self._checkpoints_written = 0
        self._checkpoints_restored = 0
        pool.attach_stats_source("sessions", self.stats)

    # -- lifecycle ----------------------------------------------------------

    def start(self, species: np.ndarray, coords: np.ndarray,
              masses: np.ndarray, config: SessionConfig = SessionConfig(),
              session_id: Optional[str] = None, seed: int = 0,
              on_frame: Optional[Callable[[Frame], None]] = None,
              retain_frames: bool = True) -> MDSession:
        """Open a session for one molecule: ``species (n,)``,
        ``coords (n, 3)``, ``masses (n,)``. The molecule is padded to
        its serving bucket (chunks share the shape class — and so the
        batch-affinity routing state — with same-size one-shot traffic)
        and tiled to ``config.n_replicas`` MD replicas with
        Maxwell-Boltzmann velocities drawn from ``seed`` on the first
        chunk."""
        n = int(np.asarray(species).shape[0])
        bucket = assign_bucket(n, self.pool.serve.buckets())
        sp, co, mask = pad_replicas(np.asarray(species), np.asarray(coords),
                                    config.n_replicas,
                                    capacity=bucket.capacity)
        m = np.ones((bucket.capacity,), np.float32)
        m[:n] = np.asarray(masses, np.float32)
        m = np.broadcast_to(m, mask.shape).copy()
        with self._lock:
            self._n_seq += 1
            if session_id is None:
                session_id = f"sess{self._n_seq:04d}-n{n}-s{seed}"
        session_id = _ID_RE.sub("_", session_id)
        session = MDSession(
            session_id, config, sp, mask, m, co, bucket.capacity, seed,
            os.path.join(self.root, session_id), on_frame=on_frame,
            retain_frames=retain_frames)
        self._launch(session)
        return session

    def resume_all(self, on_frame: Optional[Callable[[Frame], None]] = None,
                   retain_frames: bool = True) -> List[MDSession]:
        """Scan the checkpoint root and resume every session that has a
        valid checkpoint (``latest_step()`` skips corrupted steps via
        digest verification) and is not already live in this manager.
        The un-checkpointed tail replays deterministically; frames from
        replayed chunks are re-emitted with their original indices
        (at-least-once delivery across restarts). Sessions whose
        checkpoints say they finished are returned as ``done`` without
        a driver thread."""
        out = []
        for name in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, name)
            with self._lock:
                live = name in self._sessions
            if not os.path.isdir(d) or live:
                continue
            cm = CheckpointManager(d, keep=self.keep)
            step = cm.latest_step()
            if step is None:
                continue        # nothing restorable (no valid step yet)
            session = self._rebuild(name, cm, step, on_frame, retain_frames)
            with self._lock:
                self._checkpoints_restored += 1
            REGISTRY.counter("session_events_total",
                             event="checkpoint_restored").inc()
            session.n_restores += 1
            if session.chunks_done >= session.config.n_chunks:
                with self._lock:
                    self._sessions[session.session_id] = session
                session._finish("done")
            else:
                self._launch(session)
            out.append(session)
        return out

    def _rebuild(self, name: str, cm: CheckpointManager, step: int,
                 on_frame, retain_frames) -> MDSession:
        arrays = cm.restore_arrays(step)
        extra = cm.extra(step)
        cfg_d = dict(extra["config"])
        cfg_d["md"] = MDConfig(**cfg_d["md"])
        config = SessionConfig(**cfg_d)
        nlist = NeighborList(
            senders=arrays["nl/senders"], receivers=arrays["nl/receivers"],
            edge_mask=arrays["nl/edge_mask"],
            ref_coords=arrays["nl/ref_coords"],
            overflow=arrays["nl/overflow"],
            n_rebuilds=arrays["nl/n_rebuilds"])
        state = ReplicaState(
            coords=arrays["coords"], veloc=arrays["veloc"],
            forces=arrays["forces"], e_pot=arrays["e_pot"],
            nlist=nlist, missed=arrays["missed"])
        session = MDSession(
            name, config, arrays["species"], arrays["mask"],
            arrays["masses"], arrays["init_coords"],
            int(extra["bucket_capacity"]), int(extra["seed"]),
            os.path.join(self.root, name), on_frame=on_frame,
            retain_frames=retain_frames, state=state,
            chunks_done=int(extra["chunks_done"]),
            steps_done=int(extra["steps_done"]))
        session.last_artifact_version = extra.get("artifact_version", "")
        return session

    def _launch(self, session: MDSession) -> None:
        with self._lock:
            self._sessions[session.session_id] = session
            t = threading.Thread(target=self._drive, args=(session,),
                                 name=f"md-session-{session.session_id}",
                                 daemon=True)
            self._threads[session.session_id] = t
        session.status = "running"
        t.start()

    def close(self, cancel: bool = False,
              timeout: Optional[float] = None) -> None:
        """Join every driver thread; with ``cancel`` sessions stop at
        their next chunk boundary first (checkpointed progress survives
        for a later ``resume_all``)."""
        with self._lock:
            sessions = list(self._sessions.values())
            threads = list(self._threads.values())
        if cancel:
            for s in sessions:
                s.cancel()
        for t in threads:
            t.join(timeout)

    # -- driving ------------------------------------------------------------

    def _drive(self, session: MDSession) -> None:
        cfg = session.config
        try:
            while (session.chunks_done < cfg.n_chunks
                   and not session._cancel.is_set()):
                if self.faults is not None:
                    self.faults.fire(session, session.chunks_done)
                if session._cancel.is_set():
                    break
                self._run_chunk(session)
            if session._cancel.is_set() \
                    and session.chunks_done < cfg.n_chunks:
                session._finish("cancelled")
            else:
                session._finish("done")
        except BaseException as e:
            # frame-loss SLO feed: frames the trajectory promised but
            # will never stream (ceil covers a ragged final chunk)
            expected = math.ceil(cfg.n_steps / cfg.record_every)
            lost = max(0, expected - session.frames_emitted)
            if lost:
                REGISTRY.counter("session_frames_total",
                                 event="lost").inc(lost)
            session._finish("failed", e)

    def _run_chunk(self, session: MDSession) -> None:
        cfg = session.config
        ci = session.chunks_done
        length = cfg.chunk_len(ci)
        fn = self._make_chunk_fn(session, length)
        backoff = cfg.backoff_s
        attempt = 0
        min_tier: Optional[str] = None   # guardrail escalation target
        esc_used = 0
        while True:
            if session._cancel.is_set():
                return
            try:
                handle = self.pool.submit_chunk(
                    fn, session.bucket_capacity,
                    preferred_replica=session.preferred_replica,
                    session_id=session.session_id, chunk_idx=ci,
                    min_tier=min_tier)
            except SchedulerOverloaded as e:
                # typed retry-with-backoff on shed: the scheduler tells
                # us roughly when one batch will have drained; full
                # jitter (uniform over [0, wait]) decorrelates sessions
                # shed by the same burst
                attempt += 1
                with self._lock:
                    self._shed_retries += 1
                REGISTRY.counter("session_events_total",
                                 event="shed_retry").inc()
                if attempt > cfg.max_retries:
                    raise
                session._cancel.wait(session._rng.uniform(0.0, min(
                    max(e.retry_after_s, backoff), cfg.backoff_max_s)))
                backoff = min(backoff * 2, cfg.backoff_max_s)
                continue
            try:
                new_state, records, art = handle.result(
                    timeout_s=cfg.result_timeout_s)
            except GuardrailViolation as e:
                # the chunk's physics failed its guardrails (non-finite
                # energies, drift past the limit): state is untouched —
                # re-submit the same pure chunk one precision tier above
                # the mode that produced the violation
                try:
                    target = next_tier(e.detail.get("mode", cfg.md.mode))
                except ValueError:
                    target = None
                if target is None or esc_used >= cfg.max_escalations:
                    raise      # top of the ladder / budget spent: real
                esc_used += 1  # physics or broken weights, fail loudly
                session.n_escalations += 1
                with self._lock:
                    self._chunk_escalations += 1
                REGISTRY.counter("session_events_total",
                                 event="chunk_escalated").inc()
                min_tier = target
                session.preferred_replica = None
                continue
            except BaseException as e:
                # replica died mid-chunk, the per-chunk deadline fired
                # (typed RequestTimeout), or the requeue budget ran out:
                # state is untouched on the host — re-submit the same
                # pure chunk, dropping stickiness so JSQ picks a survivor
                attempt += 1
                session.n_retries += 1
                with self._lock:
                    self._chunks_retried += 1
                    if isinstance(e, RequestTimeout):
                        self._chunk_timeouts += 1
                REGISTRY.counter("session_events_total",
                                 event="chunk_retried").inc()
                if attempt > cfg.max_retries:
                    raise
                session.preferred_replica = None
                session._cancel.wait(session._rng.uniform(0.0, backoff))
                backoff = min(backoff * 2, cfg.backoff_max_s)
                continue
            break
        session.state = new_state
        session.steps_done += length
        session.chunks_done = ci + 1
        session.preferred_replica = handle.replica_id
        session.last_artifact_version = art
        if art not in session.artifact_versions:
            session.artifact_versions.append(art)
        with self._lock:
            self._chunks_completed += 1
        REGISTRY.counter("session_events_total",
                         event="chunk_completed").inc()
        self._emit(session, ci, length, records,
                   handle.replica_id if handle.replica_id is not None else -1,
                   art)
        if (session.chunks_done % cfg.checkpoint_every == 0
                or session.chunks_done >= cfg.n_chunks):
            self._checkpoint(session)

    def _make_chunk_fn(self, session: MDSession, length: int):
        """One chunk as a pure closure over the session's current host
        state: everything is device_put onto the *executing* replica's
        device (replicas pin their weights; mixing committed devices in
        one computation is an error), integrated, pulled back to host."""
        cfg = session.config
        state = session.state
        species, mask = session.species, session.mask
        masses, init_coords = session.masses, session.init_coords
        seed, temperature = session.seed, cfg.temperature_K

        def fn(engine):
            md_eng = self._md_engine_for(engine, cfg.md)
            dev = engine.device
            sp = jax.device_put(species, dev)
            mk = jax.device_put(mask, dev)
            ms = jax.device_put(masses, dev)
            if state is None:
                key = jax.device_put(
                    np.asarray(jax.random.PRNGKey(seed)), dev)
                st = md_eng.init_state(
                    key, sp, jax.device_put(init_coords, dev), mk, ms,
                    temperature_K=temperature)
            else:
                st = jax.device_put(state, dev)
            new_state, records = md_eng.run(
                st, sp, mk, ms, n_steps=length,
                record_every=cfg.record_every)
            return (jax.device_get(new_state), records,
                    engine.artifact_version)

        return fn

    def _md_engine_for(self, engine, md: MDConfig) -> MDEngine:
        """Per-(serving engine, MDConfig) cache: ``md_engine()`` builds
        a fresh MDEngine (fresh jit cache) per call — without this every
        chunk would recompile its segments. Weak keys let swapped-out
        engines drop their compiled programs."""
        # integrate at the precision of whichever replica executes the
        # chunk: on a tiered pool an escalated chunk lands on a w8a8 or
        # fp32 replica and must run *that* engine's mode, not the
        # session's nominal one (the GuardrailViolation it raises then
        # carries the actual mode for the next escalation decision)
        md = dataclasses.replace(md, mode=engine.serve.mode)
        with self._md_lock:
            per = self._md_cache.get(engine)
            if per is None:
                per = {}
                self._md_cache[engine] = per
            md_eng = per.get(md)
            if md_eng is None:
                md_eng = engine.md_engine(md=md)
                per[md] = md_eng
            return md_eng

    # -- frames + checkpoints ------------------------------------------------

    def _emit(self, session: MDSession, chunk_idx: int, length: int,
              records: Dict[str, np.ndarray], replica_id: int,
              artifact_version: str) -> None:
        cfg = session.config
        n_rec = records["e_pot"].shape[0] if "e_pot" in records else 0
        base = chunk_idx * cfg.frames_per_chunk
        s0 = chunk_idx * cfg.chunk_steps
        for i in range(n_rec):
            session._deliver(Frame(
                session_id=session.session_id, index=base + i,
                step=s0 + min((i + 1) * cfg.record_every, length),
                e_pot=np.asarray(records["e_pot"][i]),
                e_tot=np.asarray(records["e_tot"][i]),
                temperature_K=np.asarray(records["temperature_K"][i]),
                replica_id=replica_id, artifact_version=artifact_version))

    def _checkpoint(self, session: MDSession) -> None:
        st = session.state
        cfg = session.config
        tree = {
            "coords": st.coords, "veloc": st.veloc, "forces": st.forces,
            "e_pot": st.e_pot, "missed": st.missed,
            "nl": {"senders": st.nlist.senders,
                   "receivers": st.nlist.receivers,
                   "edge_mask": st.nlist.edge_mask,
                   "ref_coords": st.nlist.ref_coords,
                   "overflow": st.nlist.overflow,
                   "n_rebuilds": st.nlist.n_rebuilds},
            "species": session.species, "mask": session.mask,
            "masses": session.masses, "init_coords": session.init_coords,
            "rng_key": np.asarray(jax.random.PRNGKey(session.seed)),
        }
        extra = {
            "session_id": session.session_id,
            "chunks_done": session.chunks_done,
            "steps_done": session.steps_done,
            "bucket_capacity": session.bucket_capacity,
            "seed": session.seed,
            "artifact_version": session.last_artifact_version,
            "config": dataclasses.asdict(cfg),
        }
        cm = CheckpointManager(session.checkpoint_dir, keep=self.keep)
        t0 = time.monotonic()
        cm.save(session.chunks_done, tree, extra=extra)
        session.n_checkpoints += 1
        with self._lock:
            self._checkpoints_written += 1
        REGISTRY.counter("session_events_total",
                         event="checkpoint_written").inc()
        REGISTRY.histogram("session_checkpoint_seconds").observe(
            time.monotonic() - t0)

    # -- telemetry ----------------------------------------------------------

    def sessions(self) -> List[MDSession]:
        with self._lock:
            return list(self._sessions.values())

    def stats(self) -> Dict[str, object]:
        """The ``sessions`` section of ``pool.stats()``: per-status
        counts, chunk/checkpoint/retry counters, per-session telemetry,
        and the fault injector's counts when one is attached."""
        with self._lock:
            sessions = list(self._sessions.values())
            out: Dict[str, object] = {
                "active": sum(1 for s in sessions if s.status == "running"),
                "done": sum(1 for s in sessions if s.status == "done"),
                "failed": sum(1 for s in sessions if s.status == "failed"),
                "cancelled": sum(1 for s in sessions
                                 if s.status == "cancelled"),
                "chunks_completed": self._chunks_completed,
                "chunks_retried": self._chunks_retried,
                "shed_retries": self._shed_retries,
                "chunk_timeouts": self._chunk_timeouts,
                "chunk_escalations": self._chunk_escalations,
                "checkpoints_written": self._checkpoints_written,
                "checkpoints_restored": self._checkpoints_restored,
            }
        out["frames_emitted"] = sum(s.frames_emitted for s in sessions)
        out["per_session"] = [s.telemetry() for s in sessions]
        if self.faults is not None:
            out["faults_injected"] = self.faults.counts()
        return out
