"""Fault-tolerant checkpoint manager.

Design for 1000+-node operation:
  * atomic step directories: write to `step_N.tmp.*`, fsync, rename — a
    crash mid-write never corrupts the latest valid checkpoint, and the
    parent directory is fsynced after the rename so the *commit itself*
    is durable across power loss, not just the file contents;
  * manifest with per-array SHA-256 so a torn/bitrotten file is detected
    and that step is skipped at restore — ``restore``/``restore_arrays``
    re-verify every digest and raise :class:`CheckpointError` rather
    than returning garbage bytes;
  * keep-N garbage collection, which also sweeps `step_N.tmp.*` orphans
    left behind by a hard kill mid-``save``;
  * mesh-agnostic restore: arrays are saved UNSHARDED (host-gathered,
    numpy); `restore(..., shardings=...)` device_puts onto whatever mesh
    the new job has — elastic rescale (restart on 256 chips from a
    512-chip run, or vice versa) is a restore with different shardings,
    nothing else changes;
  * auto-resume: `latest_step()` scans for the newest *valid* step.

The streaming-MD session layer (``repro.sessions``, docs/sessions.md)
drives this manager for per-session trajectory state; its chaos tests
corrupt checkpoints on purpose and rely on the typed-error contract
here to fall back to the previous valid step.

On a real multi-host deployment the np.save path is replaced by per-host
shards of the process-local addressable data; the manifest/atomicity/restore
logic is unchanged (noted in DESIGN.md).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["CheckpointError", "CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^step_\d+\.tmp\.")


class CheckpointError(RuntimeError):
    """A checkpoint could not be restored: missing step/array, manifest
    absent or unreadable, or an on-disk digest that no longer matches
    the manifest (torn write, bitflip). Restore never hands back bytes
    it cannot vouch for — callers fall back to an earlier step via
    ``latest_step()`` instead of silently loading garbage."""


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def visit(path, x):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = x

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def _fsync_dir(path: str) -> None:
    """Flush a directory entry to disk (POSIX: rename durability needs
    an fsync of the *parent*, not just the files)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: Optional[dict] = None):
        flat = _flatten(tree)
        tmp = tempfile.mkdtemp(prefix=f"step_{step}.tmp.", dir=self.dir)
        manifest = {"step": step, "extra": extra or {}, "arrays": {}}
        try:
            for key, val in flat.items():
                arr = np.asarray(val)
                fname = hashlib.md5(key.encode()).hexdigest()[:16] + ".npy"
                fpath = os.path.join(tmp, fname)
                np.save(fpath, arr)
                with open(fpath, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                manifest["arrays"][key] = {
                    "file": fname, "sha256": digest,
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                }
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic on POSIX
            # the rename only becomes durable once the parent directory
            # entry is flushed — without this a power cut can roll the
            # commit back even though save() returned
            _fsync_dir(self.dir)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
        # sweep orphaned step_N.tmp.* dirs: a process hard-killed between
        # mkdtemp and the rename leaks its scratch dir forever otherwise
        # (the rename raced by a *live* save cannot be confused with an
        # orphan — tempfile.mkdtemp names are unique, and each save
        # renames its own tmp before ever calling _gc)
        for name in os.listdir(self.dir):
            if _TMP_RE.match(name):
                full = os.path.join(self.dir, name)
                if full != getattr(self, "_active_tmp", None):
                    shutil.rmtree(full, ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            # tmp dirs (step_N.tmp.*) never match: an uncommitted save
            # must not be offered as a restorable step
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _manifest(self, step: int) -> dict:
        d = os.path.join(self.dir, f"step_{step}")
        mpath = os.path.join(d, "manifest.json")
        if not os.path.exists(mpath):
            raise CheckpointError(
                f"step {step}: no checkpoint at {d} (or manifest missing)")
        try:
            with open(mpath) as f:
                return json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointError(
                f"step {step}: unreadable manifest: {e}") from e

    def _verified_bytes(self, step: int, key: str, meta: dict) -> str:
        """Path of an array file whose on-disk SHA-256 matches the
        manifest; :class:`CheckpointError` otherwise."""
        d = os.path.join(self.dir, f"step_{step}")
        fpath = os.path.join(d, meta["file"])
        try:
            with open(fpath, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
        except OSError as e:
            raise CheckpointError(
                f"step {step}: array {key!r} unreadable: {e}") from e
        if digest != meta["sha256"]:
            raise CheckpointError(
                f"step {step}: array {key!r} fails its SHA-256 "
                f"(torn write or bitflip) — refusing to restore")
        return fpath

    def is_valid(self, step: int) -> bool:
        d = os.path.join(self.dir, f"step_{step}")
        mpath = os.path.join(d, "manifest.json")
        if not os.path.exists(mpath):
            return False
        try:
            manifest = json.load(open(mpath))
            for key, meta in manifest["arrays"].items():
                self._verified_bytes(step, key, meta)
            return True
        except (CheckpointError, Exception):
            return False

    def latest_step(self) -> Optional[int]:
        for s in reversed(self.all_steps()):
            if self.is_valid(s):
                return s
        return None

    def restore_arrays(self, step: int) -> Dict[str, np.ndarray]:
        """Structure-free restore: every array in the manifest, keyed by
        its flattened tree path, digest-verified. This is the resume path
        for callers that rebuild their own containers from known keys
        (``repro.sessions`` restarting after a process death has no live
        `like` tree to mirror)."""
        manifest = self._manifest(step)
        out = {}
        for key, meta in manifest["arrays"].items():
            out[key] = np.load(self._verified_bytes(step, key, meta))
        return out

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of `like`. If `shardings` (same tree
        structure) is given, arrays are placed with those shardings — this
        is the elastic-rescale path.

        Every array is digest-verified against the manifest before use;
        a mismatch, a truncated file, or a key `like` expects that the
        manifest lacks raises :class:`CheckpointError` (a torn file must
        never restore silently as garbage — fall back to an earlier
        ``latest_step()``)."""
        manifest = self._manifest(step)
        flat_like = _flatten(like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for key in flat_like:
            meta = manifest["arrays"].get(key)
            if meta is None:
                raise CheckpointError(
                    f"step {step}: array {key!r} missing from the "
                    f"manifest — checkpoint does not match the requested "
                    f"structure")
            arr = np.load(self._verified_bytes(step, key, meta))
            if key in flat_sh and flat_sh[key] is not None:
                loaded[key] = jax.device_put(arr, flat_sh[key])
            else:
                loaded[key] = jax.numpy.asarray(arr)
        # rebuild tree in `like`'s structure
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        keys = list(_flatten(like).keys())
        return jax.tree_util.tree_unflatten(
            treedef, [loaded[k] for k in keys])

    def extra(self, step: int) -> dict:
        return self._manifest(step)["extra"]
