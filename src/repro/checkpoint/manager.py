"""Fault-tolerant checkpoint manager.

Design for 1000+-node operation:
  * atomic step directories: write to `step_N.tmp`, fsync, rename — a crash
    mid-write never corrupts the latest valid checkpoint;
  * manifest with per-array SHA-256 so a torn/bitrotten file is detected and
    that step is skipped at restore;
  * keep-N garbage collection;
  * mesh-agnostic restore: arrays are saved UNSHARDED (host-gathered, numpy);
    `restore(..., shardings=...)` device_puts onto whatever mesh the new job
    has — elastic rescale (restart on 256 chips from a 512-chip run, or vice
    versa) is a restore with different shardings, nothing else changes;
  * auto-resume: `latest_step()` scans for the newest *valid* step.

On a real multi-host deployment the np.save path is replaced by per-host
shards of the process-local addressable data; the manifest/atomicity/restore
logic is unchanged (noted in DESIGN.md).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def visit(path, x):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = x

    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: Optional[dict] = None):
        flat = _flatten(tree)
        tmp = tempfile.mkdtemp(prefix=f"step_{step}.tmp.", dir=self.dir)
        manifest = {"step": step, "extra": extra or {}, "arrays": {}}
        try:
            for key, val in flat.items():
                arr = np.asarray(val)
                fname = hashlib.md5(key.encode()).hexdigest()[:16] + ".npy"
                fpath = os.path.join(tmp, fname)
                np.save(fpath, arr)
                with open(fpath, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                manifest["arrays"][key] = {
                    "file": fname, "sha256": digest,
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                }
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic on POSIX
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def is_valid(self, step: int) -> bool:
        d = os.path.join(self.dir, f"step_{step}")
        mpath = os.path.join(d, "manifest.json")
        if not os.path.exists(mpath):
            return False
        try:
            manifest = json.load(open(mpath))
            for key, meta in manifest["arrays"].items():
                fpath = os.path.join(d, meta["file"])
                with open(fpath, "rb") as f:
                    if hashlib.sha256(f.read()).hexdigest() != meta["sha256"]:
                        return False
            return True
        except Exception:
            return False

    def latest_step(self) -> Optional[int]:
        for s in reversed(self.all_steps()):
            if self.is_valid(s):
                return s
        return None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of `like`. If `shardings` (same tree
        structure) is given, arrays are placed with those shardings — this is
        the elastic-rescale path."""
        d = os.path.join(self.dir, f"step_{step}")
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        flat_like = _flatten(like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for key in flat_like:
            meta = manifest["arrays"][key]
            arr = np.load(os.path.join(d, meta["file"]))
            if key in flat_sh and flat_sh[key] is not None:
                loaded[key] = jax.device_put(arr, flat_sh[key])
            else:
                loaded[key] = jax.numpy.asarray(arr)
        # rebuild tree in `like`'s structure
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        keys = list(_flatten(like).keys())
        return jax.tree_util.tree_unflatten(
            treedef, [loaded[k] for k in keys])

    def extra(self, step: int) -> dict:
        d = os.path.join(self.dir, f"step_{step}")
        return json.load(open(os.path.join(d, "manifest.json")))["extra"]
