"""End-to-end paper-experiment pipeline (Tables II/III/IV, Fig. 3).

Runs: FP32 training -> QAT finetunes (GAQ W4A8, naive INT8, Degree-Quant,
SVQ-KMeans) -> accuracy eval -> LEE eval -> NVE stability -> latency/memory
microbenchmark. Saves checkpoints + metrics JSON under artifacts/so3/ so the
benchmark harness can re-render tables without retraining.

Run:  PYTHONPATH=src python -m repro.training.pipeline [--fast]
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lee, make_codebook, random_rotations
from repro.data.synthetic_md import sample_dataset_md, make_ff
from repro.md.nve import (energy_drift_rate, init_state, kinetic_energy,
                          nve_trajectory)
from repro.models import so3krates as so3
from repro.training.so3_trainer import TrainConfig, evaluate, train

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "so3")

BASE = dict(feat=64, vec_feat=16, n_layers=3)
METHODS = {
    "fp32": dict(quant="none"),
    # dir_bits=12 (4096-pt codebook, delta=0.04 rad, 20 bits/vector) keeps
    # QAT CPU-tractable; LEE is also evaluated with a 16-bit codebook swap
    # (the codebook is not trained, so eval-time refinement is valid).
    "gaq_w4a8": dict(quant="gaq_w4a8", dir_bits=12),
    "naive_int8": dict(quant="naive_int8", robust_attention=False),
    "degree_quant": dict(quant="degree_quant", robust_attention=False),
    "svq_kmeans": dict(quant="svq_kmeans", robust_attention=False,
                       dir_bits=12),
}

# masses for azobenzene atom order (C*12, N*2, H*10), amu
MASSES = jnp.array([12.011] * 12 + [14.007] * 2 + [1.008] * 10)


def save_params(path: str, params: Dict[str, jnp.ndarray]):
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path: str) -> Dict[str, jnp.ndarray]:
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


def lee_eval(cfg, params, data, n_rot: int = 8, n_cfg: int = 8) -> float:
    codebook = make_codebook(cfg.dir_bits) if cfg.quant != "none" else None
    species = data["species"]
    rots = random_rotations(jax.random.PRNGKey(123), n_rot)
    force_fn = jax.jit(lambda c: so3.forces(params, cfg, species, c, codebook))
    errs = []
    for i in range(n_cfg):
        coords = data["coords"][i]
        for r in range(n_rot):
            errs.append(float(lee(force_fn, coords, rots[r])))
    return float(np.mean(errs))


def nve_eval(cfg, params, data, n_steps: int, dt_fs: float = 0.5,
             record_every: int = 50):
    """NVE run with the learned force field; returns energies + drift rate."""
    codebook = make_codebook(cfg.dir_bits) if cfg.quant != "none" else None
    species = data["species"]
    e_scale = float(data["e_scale"])
    force_fn = lambda c: so3.forces(params, cfg, species, c, codebook) * e_scale
    energy_fn = lambda c: so3.energy(params, cfg, species, c, codebook) * e_scale
    eq, _, _ = make_ff()
    state = init_state(jax.random.PRNGKey(7), eq, MASSES, force_fn, 300.0)
    run = jax.jit(lambda s: nve_trajectory(s, MASSES, force_fn, energy_fn,
                                           dt_fs, n_steps, record_every))
    t0 = time.monotonic()
    _, energies = run(state)
    energies.block_until_ready()
    drift = energy_drift_rate(energies, dt_fs, record_every, 24)
    blew_up = bool(~np.isfinite(np.asarray(energies)).all()
                   or np.abs(np.asarray(energies) - float(energies[0])).max()
                   > 100.0)
    return {
        "energies": np.asarray(energies).tolist(),
        "drift_ev_per_atom_ps": drift,
        "blew_up": blew_up,
        "wall_s": time.monotonic() - t0,
        "n_steps": n_steps,
        "dt_fs": dt_fs,
    }


def latency_eval(cfg, params, dim: int = 2048, n_mats: int = 8) -> Dict[str, float]:
    """CPU bandwidth-multiplier microbenchmark (Table IV analogue).

    The real model's weights (~320 KB) fit in L2, so we time a *scaled*
    weight-streaming workload: n_mats dim x dim matvecs (weight working set
    128 MB fp32 — far beyond LLC), the shape of a batch-1 inference pass.
    Compute (one fma per weight) is identical across precisions; only the
    bytes streamed from DRAM differ. Reported alongside the exact model
    memory footprint per precision.
    """
    from repro.core import abs_max_scale, quantize

    key = jax.random.PRNGKey(0)
    mats = [jax.random.normal(jax.random.fold_in(key, i), (dim, dim))
            for i in range(n_mats)]
    scales = [abs_max_scale(w, 8) for w in mats]
    ws8 = [quantize(w, s, 8) for w, s in zip(mats, scales)]
    ws4 = [w.view(jnp.uint8)[:, :dim // 2].copy() for w in ws8]  # packed bytes
    x = jnp.ones((dim,), jnp.float32)
    results: Dict[str, float] = {}
    reps = 10

    def bench(fn, *args):
        jax.block_until_ready(fn(*args))  # warm/compile
        t0 = time.monotonic()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.monotonic() - t0) / reps * 1e6  # us

    # --- weight-I/O row: stream the full weight working set through DRAM.
    # elementwise touch reads+writes N bytes; traffic scales with precision.
    @jax.jit
    def touch32(ws):
        return [w + jnp.float32(1) for w in ws]

    @jax.jit
    def touch8(ws):
        return [w + jnp.int8(1) for w in ws]

    @jax.jit
    def touch4(ws):
        return [w + jnp.uint8(1) for w in ws]

    results["weight_io_fp32_us"] = bench(touch32, mats)
    results["weight_io_int8_us"] = bench(touch8, ws8)
    results["weight_io_int4_us"] = bench(touch4, ws4)

    # --- compute row: the f32 GEMV itself (identical across precisions once
    # dequant is fused; CPU XLA cannot fuse it, TPU Pallas kernel does).
    @jax.jit
    def gemv(ws, x):
        acc = 0.0
        for w in ws:
            acc = acc + jnp.sum(x @ w)
        return acc

    results["gemv_us"] = bench(gemv, mats, x)

    # --- quant-overhead row: dequantize int8 -> f32 with per-tensor scale.
    @jax.jit
    def dequant(ws, scales):
        return [w.astype(jnp.float32) * s for w, s in zip(ws, scales)]

    results["quant_overhead_us"] = bench(dequant, ws8, scales)

    results["bytes_fp32"] = int(n_mats * dim * dim * 4)
    results["bytes_int8"] = int(n_mats * dim * dim)
    results["bytes_int4"] = int(n_mats * dim * dim // 2)

    # exact model footprint per precision (weights only)
    n_weights = int(sum(np.asarray(v).size for v in params.values()))
    results["model_bytes_fp32"] = n_weights * 4
    results["model_bytes_w8"] = n_weights
    results["model_bytes_w4"] = n_weights // 2
    return results


def main(fast: bool = False):
    os.makedirs(ART, exist_ok=True)
    key = jax.random.PRNGKey(0)
    n_train, n_test = (96, 32) if fast else (384, 128)
    # rMD17 protocol: train/test frames drawn from a 300K MD trajectory
    data = sample_dataset_md(key, n_train + n_test)
    train_data = {**data, "coords": data["coords"][:n_train],
                  "energy": data["energy"][:n_train],
                  "forces": data["forces"][:n_train]}
    test_data = {**data, "coords": data["coords"][n_train:],
                 "energy": data["energy"][n_train:],
                 "forces": data["forces"][n_train:]}

    fp32_epochs = 15 if fast else 150
    qat_epochs = 6 if fast else 40
    warm = 2 if fast else 5
    nve_steps = 2000 if fast else 40000

    metrics: Dict[str, dict] = {"units": {
        "e_scale_eV": float(data["e_scale"]),
        "note": "MAEs stored in scaled units; multiply by e_scale*1000 for meV"}}

    # ---- FP32 baseline (resumes from checkpoint if present) -----------------
    cfg32 = so3.So3kratesConfig(**BASE, **METHODS["fp32"])
    t0 = time.monotonic()
    fp32_ckpt = os.path.join(ART, "ckpt_fp32.npz")
    if os.path.exists(fp32_ckpt) and not os.environ.get("PIPELINE_FRESH"):
        params32 = load_params(fp32_ckpt)
        hist = {"loss": [float("nan")]}
        print("[fp32] resumed from", fp32_ckpt, flush=True)
    else:
        params32, hist = train(cfg32, train_data,
                               TrainConfig(epochs=fp32_epochs, warmup_epochs=0,
                                           batch_size=32, lr=5e-3), verbose=True)
        save_params(fp32_ckpt, params32)
    ev = evaluate(cfg32, params32, test_data)
    metrics["fp32"] = {**ev, "train_s": time.monotonic() - t0,
                       "final_loss": hist["loss"][-1]}
    print("[fp32]", metrics["fp32"], flush=True)

    # ---- QAT finetunes (resume from checkpoints when present) ----------------
    for name in ["gaq_w4a8", "naive_int8", "degree_quant", "svq_kmeans"]:
        cfg = so3.So3kratesConfig(**BASE, **METHODS[name])
        t0 = time.monotonic()
        ckpt = os.path.join(ART, f"ckpt_{name}.npz")
        if os.path.exists(ckpt) and not os.environ.get("PIPELINE_FRESH"):
            params = load_params(ckpt)
            hist = {"loss": [0.0]}
            print(f"[{name}] resumed from {ckpt}", flush=True)
        else:
            params, hist = train(cfg, train_data,
                                 TrainConfig(epochs=qat_epochs,
                                             warmup_epochs=warm,
                                             batch_size=32, lr=1e-3,
                                             lee_weight=1.0, lee_rotations=2),
                                 init=params32, verbose=True)
            save_params(ckpt, params)
        ev = evaluate(cfg, params, test_data)
        metrics[name] = {**ev, "train_s": time.monotonic() - t0,
                         "final_loss": hist["loss"][-1],
                         "diverged": not np.isfinite(hist["loss"][-1])}
        print(f"[{name}]", metrics[name], flush=True)

    # ---- LEE (Table III) ---------------------------------------------------
    for name in ["fp32", "gaq_w4a8", "naive_int8", "degree_quant"]:
        cfg = so3.So3kratesConfig(**BASE, **METHODS[name])
        params = load_params(os.path.join(ART, f"ckpt_{name}.npz"))
        metrics[name]["lee"] = lee_eval(cfg, params, test_data)
        print(f"[lee] {name}: {metrics[name]['lee']:.6f}", flush=True)
    # eval-only codebook refinement: same gaq checkpoint, 16-bit directions
    cfg16 = so3.So3kratesConfig(**BASE, quant="gaq_w4a8", dir_bits=16)
    params = load_params(os.path.join(ART, "ckpt_gaq_w4a8.npz"))
    metrics["gaq_w4a8"]["lee_dir16"] = lee_eval(cfg16, params, test_data,
                                                n_rot=4, n_cfg=4)
    print(f"[lee] gaq dir16: {metrics['gaq_w4a8']['lee_dir16']:.6f}",
          flush=True)

    # ---- NVE (Fig. 3) ------------------------------------------------------
    for name in ["fp32", "gaq_w4a8", "naive_int8"]:
        cfg = so3.So3kratesConfig(**BASE, **METHODS[name])
        params = load_params(os.path.join(ART, f"ckpt_{name}.npz"))
        metrics[name]["nve"] = nve_eval(cfg, params, test_data, nve_steps)
        print(f"[nve] {name}: drift={metrics[name]['nve']['drift_ev_per_atom_ps']:.2e} "
              f"blew_up={metrics[name]['nve']['blew_up']}", flush=True)

    # ---- latency / memory (Table IV) ---------------------------------------
    metrics["latency"] = latency_eval(cfg32, params32)
    print("[latency]", metrics["latency"], flush=True)

    with open(os.path.join(ART, "metrics.json"), "w") as f:
        json.dump(metrics, f, indent=2)
    print("pipeline done ->", os.path.join(ART, "metrics.json"))


if __name__ == "__main__":
    main(fast="--fast" in sys.argv)
