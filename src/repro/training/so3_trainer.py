"""QAT trainer for the So3krates GAQ model (paper §IV-A protocol).

Implements the finetune-only strategy: train an FP32 model to convergence,
then run quantization-aware finetuning with
  * branch-separated staged warm-up (vector quantizers frozen for the first
    `warmup_epochs`),
  * LEE regularization on the force outputs (quant modes only),
  * Adam with cosine decay.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lee_regularizer, make_codebook
from repro.models import so3krates as so3
from repro.optim.adamw import AdamW, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    epochs: int = 60
    warmup_epochs: int = 10      # vector-quant freeze (paper: 10/80)
    batch_size: int = 8
    lr: float = 2e-3
    force_weight: float = 10.0
    lee_weight: float = 0.1      # applied to quantized models only
    lee_rotations: int = 1
    seed: int = 0


def _batched_ef(params, cfg, species, coords, codebook):
    """Batched energy+forces. coords: (B, n, 3) -> (B,), (B, n, 3)."""
    return jax.vmap(lambda c: so3.energy_and_forces(params, cfg, species, c,
                                                    codebook))(coords)


def make_loss_fn(cfg: so3.So3kratesConfig, species: jnp.ndarray,
                 codebook: Optional[jnp.ndarray], tcfg: TrainConfig):
    use_lee = cfg.quant != "none" and tcfg.lee_weight > 0

    def loss_fn(params, coords, e_ref, f_ref, key):
        e, f = _batched_ef(params, cfg, species, coords, codebook)
        l_e = jnp.mean((e - e_ref) ** 2)
        l_f = jnp.mean(jnp.sum((f - f_ref) ** 2, axis=-1))
        total = l_e + tcfg.force_weight * l_f
        if use_lee:
            force_fn = lambda c: so3.forces(params, cfg, species, c, codebook)
            l_lee = lee_regularizer(force_fn, coords[0], key,
                                    tcfg.lee_rotations)
            total = total + tcfg.lee_weight * l_lee
        return total, (l_e, l_f)

    return loss_fn


def train(cfg: so3.So3kratesConfig, data: Dict[str, jnp.ndarray],
          tcfg: TrainConfig,
          init: Optional[so3.Params] = None,
          verbose: bool = False) -> Tuple[so3.Params, Dict[str, list]]:
    """Train (or QAT-finetune, when `init` is given) on a synthetic-MD dict."""
    key = jax.random.PRNGKey(tcfg.seed)
    key, pkey = jax.random.split(key)
    species = data["species"]
    codebook = make_codebook(cfg.dir_bits) if cfg.quant != "none" else None
    params = init if init is not None else so3.init_params(pkey, cfg)

    n = data["coords"].shape[0]
    steps_per_epoch = max(n // tcfg.batch_size, 1)
    total_steps = tcfg.epochs * steps_per_epoch
    opt = AdamW(lr=cosine_schedule(tcfg.lr, total_steps // 20, total_steps),
                grad_clip=10.0)
    opt_state = opt.init(params)

    warm_cfg = dataclasses.replace(cfg, freeze_vec_quant=True)

    def make_step(step_cfg):
        loss_fn = make_loss_fn(step_cfg, species, codebook, tcfg)

        @jax.jit
        def step(params, opt_state, coords, e_ref, f_ref, key):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, coords, e_ref, f_ref, key)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss, aux

        return step

    step_warm = make_step(warm_cfg)
    step_full = make_step(cfg)

    history = {"loss": [], "e_mse": [], "f_mse": []}
    for epoch in range(tcfg.epochs):
        key, ekey = jax.random.split(key)
        perm = jax.random.permutation(ekey, n)
        step_fn = step_warm if epoch < tcfg.warmup_epochs else step_full
        ep_loss = ep_e = ep_f = 0.0
        for s in range(steps_per_epoch):
            idx = perm[s * tcfg.batch_size:(s + 1) * tcfg.batch_size]
            key, skey = jax.random.split(key)
            params, opt_state, loss, (l_e, l_f) = step_fn(
                params, opt_state, data["coords"][idx], data["energy"][idx],
                data["forces"][idx], skey)
            ep_loss += float(loss); ep_e += float(l_e); ep_f += float(l_f)
        history["loss"].append(ep_loss / steps_per_epoch)
        history["e_mse"].append(ep_e / steps_per_epoch)
        history["f_mse"].append(ep_f / steps_per_epoch)
        if verbose and (epoch % 5 == 0 or epoch == tcfg.epochs - 1):
            print(f"epoch {epoch:3d} loss {history['loss'][-1]:.5f} "
                  f"E-mse {history['e_mse'][-1]:.5f} F-mse {history['f_mse'][-1]:.5f}")
    return params, history


def evaluate(cfg: so3.So3kratesConfig, params: so3.Params,
             data: Dict[str, jnp.ndarray], batch: int = 32) -> Dict[str, float]:
    """Energy/force MAE in the dataset's units (eV -> report meV upstream)."""
    species = data["species"]
    codebook = make_codebook(cfg.dir_bits) if cfg.quant != "none" else None
    ef = jax.jit(partial(_batched_ef, cfg=cfg, species=species,
                         codebook=codebook))
    maes_e, maes_f = [], []
    n = data["coords"].shape[0]
    for s in range(0, n, batch):
        e, f = ef(params, coords=data["coords"][s:s + batch])
        maes_e.append(jnp.abs(e - data["energy"][s:s + batch]))
        maes_f.append(jnp.abs(f - data["forces"][s:s + batch]).mean((-1, -2)))
    return {
        "e_mae": float(jnp.concatenate(maes_e).mean()),
        "f_mae": float(jnp.concatenate(maes_f).mean()),
    }
