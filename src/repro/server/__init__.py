"""repro.server — the online serving layer over ``repro.serving``.

Where ``repro.serving`` answers "given a batch of molecules, run them
fast", this package answers the production questions above it: requests
arriving one at a time over the wall clock, latency deadlines, batch
formation under load, and a packed on-disk artifact so cold start never
touches fp32 weights.

* :class:`MicroBatchScheduler` / :class:`SchedulerConfig` — dynamic
  micro-batching over the engine's bucket ladder: per-shape-class
  admission queues, flushed on ``max_batch`` or a ``deadline_ms``
  batching deadline, request->result identity preserved under
  out-of-order flushes (``scheduler.py``);
* :func:`save_artifact` / :func:`load_artifact` / :func:`load_engine` —
  versioned single-``.npz`` packed-weight artifacts (nibble-packed w4,
  int8 w8, scales, configs) with checksum/version validation; bit-exact
  reload, cold start skips quantization entirely (``artifact.py``);
* :func:`make_traffic` / :func:`run_open_loop` / :func:`run_closed_loop`
  — seeded Poisson traffic over mixed molecule sizes and the drivers
  that replay it (``traffic.py``);
* :func:`latency_summary` / :func:`flush_summary` — p50/p95/p99,
  throughput, queue-depth/occupancy accounting (``stats.py``).

See docs/server.md for semantics and knobs; ``benchmarks/
server_bench.py`` measures dynamic batching against per-request serving
and writes ``BENCH_server.json``.
"""
from repro.server.artifact import (ARTIFACT_MAGIC, ARTIFACT_VERSION,
                                   ArtifactError, LoadedArtifact,
                                   ensure_mode_matches, load_artifact,
                                   load_engine, save_artifact)
from repro.server.scheduler import (BatchQueue, MicroBatchScheduler,
                                    RequestHandle, RequestTimeout,
                                    SchedulerClosed, SchedulerConfig,
                                    SchedulerOverloaded)
from repro.server.stats import FlushRecord, flush_summary, latency_summary
from repro.server.traffic import (RateStage, SizeClass, TrafficConfig,
                                  TrafficResult, calibrate_service_time,
                                  draw_graphs, make_step_traffic,
                                  make_traffic, run_closed_loop,
                                  run_open_loop, stage_summaries)

__all__ = [
    "ARTIFACT_MAGIC", "ARTIFACT_VERSION", "ArtifactError", "LoadedArtifact",
    "ensure_mode_matches", "load_artifact", "load_engine", "save_artifact",
    "BatchQueue", "MicroBatchScheduler", "RequestHandle", "RequestTimeout",
    "SchedulerClosed", "SchedulerConfig", "SchedulerOverloaded",
    "FlushRecord", "flush_summary", "latency_summary",
    "RateStage", "SizeClass", "TrafficConfig", "TrafficResult",
    "calibrate_service_time", "draw_graphs", "make_step_traffic",
    "make_traffic", "run_closed_loop", "run_open_loop", "stage_summaries",
]
