"""Packed quantized-model artifacts: the on-disk serving representation.

Every prior entry point (engine construction, the MD bridge, the serve
CLI) starts from an fp32 param tree and quantizes it at load time — the
paper's W4A8 memory win (4x) exists in HBM but not on disk, and cold
start pays fp32 materialization + a full quantization pass on every
process start. This module makes the *serving* representation the
artifact: one versioned ``.npz`` holding the ``QuantizedParams`` tree
exactly as the engine consumes it — nibble-packed uint8 ``w4`` data,
int8 ``w8`` data, fp32 per-column scales, fp32 passthrough leaves — plus
the ``ServeConfig`` and ``So3kratesConfig`` it was quantized for, so

* **cold start** is ``load_engine(path)``: deserialize + compile, no
  fp32 tree, no quantization pass (measured in ``benchmarks/
  server_bench.py`` against the fp32 route);
* **bit-exactness** is structural, not approximate: the arrays the
  loaded engine serves with are byte-for-byte the saved ones, so
  energies/forces are bit-identical to the source engine's
  (``tests/test_server.py`` pins this);
* **integrity** follows ``repro.checkpoint.CheckpointManager``'s rules:
  atomic write (temp file + rename), a manifest with per-array SHA-256,
  and clean ``ArtifactError``s — never silent garbage — for truncated
  files, checksum mismatches, and format-version skew.

Layout inside the ``.npz``::

    __manifest__          JSON (utf-8 bytes as a uint8 array): magic,
                          version, mode, model_cfg, serve_cfg, fp32_bytes,
                          per-leaf {kind, has_scale, sha256(data)}
    q/<name>/data         QTensor payload (int8 / packed uint8 / fp32)
    q/<name>/scale        per-output-channel fp32 scales (quantized kinds)
    a/<name>              non-QTensor fp32 leaves (embeddings, norms, ...)

Version bumps whenever the layout or the semantics of any field change;
``load_artifact`` refuses other versions rather than guessing.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import zipfile
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.models import so3krates as so3
from repro.serving.engine import QuantizedEngine, ServeConfig
from repro.serving.qparams import QTensor, QuantizedParams, serving_bytes

__all__ = ["ArtifactError", "ARTIFACT_MAGIC", "ARTIFACT_VERSION",
           "save_artifact", "load_artifact", "load_engine", "LoadedArtifact",
           "ensure_mode_matches"]

ARTIFACT_MAGIC = "repro-quantized-so3-artifact"
ARTIFACT_VERSION = 1


class ArtifactError(RuntimeError):
    """A packed artifact could not be read: truncated/corrupt file,
    checksum mismatch, or a format version this code does not speak."""


def ensure_mode_matches(artifact_mode: str, serve_mode: str) -> None:
    """The single mode-compatibility rule for packed weights: an
    artifact's payloads *are* its quantization mode, so a serving
    config may override any other knob but never ``mode``. Shared by
    ``load_engine`` and the cluster's ``from_artifact``/``swap_artifact``
    so the rule (and its error) cannot drift between entry points."""
    if serve_mode != artifact_mode:
        raise ArtifactError(
            f"ServeConfig.mode {serve_mode!r} != artifact mode "
            f"{artifact_mode!r}: packed weights cannot change mode — "
            "re-export from the fp32 checkpoint instead")


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


@dataclasses.dataclass(frozen=True)
class LoadedArtifact:
    """A deserialized artifact, ready to become an engine."""
    qparams: QuantizedParams
    model_cfg: so3.So3kratesConfig
    serve: ServeConfig
    fp32_bytes: int          # footprint of the fp32 tree this came from
    file_bytes: int          # size of the artifact on disk
    # short content tag over the per-leaf SHA-256s: two artifacts carry
    # the same tag iff their weight payloads are byte-identical. The
    # cluster stamps this into every result during rolling hot swaps
    # (MoleculeResult.artifact_version), so clients can tell which
    # weights answered.
    version_tag: str = ""

    @property
    def compression_x(self) -> float:
        return self.fp32_bytes / max(self.file_bytes, 1)


def _version_tag(leaves: Dict[str, dict]) -> str:
    """Deterministic content tag: SHA-256 over the sorted per-leaf
    digests (weights only — retagging does not depend on configs or
    file layout), truncated for log-friendliness."""
    h = hashlib.sha256()
    for name in sorted(leaves):
        h.update(name.encode("utf-8"))
        h.update(leaves[name]["sha256"].encode("ascii"))
    return h.hexdigest()[:12]


def save_artifact(path: str, engine: QuantizedEngine) -> int:
    """Serialize an engine's serving-format parameters + configs to one
    versioned ``.npz`` at ``path``. Atomic (temp file + rename): a crash
    mid-write never leaves a half-artifact at the destination. Returns
    the artifact's byte size."""
    arrays: Dict[str, np.ndarray] = {}
    leaves = {}
    for name, v in engine.qparams.items():
        if isinstance(v, QTensor):
            data = np.asarray(v.data)
            arrays[f"q/{name}/data"] = data
            leaf = {"kind": v.kind, "has_scale": v.scale is not None,
                    "sha256": _sha256(data)}
            if v.scale is not None:
                arrays[f"q/{name}/scale"] = np.asarray(v.scale)
        else:
            data = np.asarray(v)
            arrays[f"a/{name}"] = data
            leaf = {"kind": "array", "has_scale": False,
                    "sha256": _sha256(data)}
        leaves[name] = leaf
    manifest = {
        "magic": ARTIFACT_MAGIC,
        "version": ARTIFACT_VERSION,
        "mode": engine.serve.mode,
        "model_cfg": dataclasses.asdict(engine.model_cfg),
        "serve_cfg": dataclasses.asdict(engine.serve),
        "fp32_bytes": engine.memory_report()["fp32_bytes"],
        "serving_bytes": serving_bytes(engine.qparams),
        "leaves": leaves,
    }
    # utf-8 bytes, not a numpy unicode array (dtype <U pads to 4 B/char)
    arrays["__manifest__"] = np.frombuffer(
        json.dumps(manifest).encode("utf-8"), dtype=np.uint8)

    directory = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(suffix=".npz.tmp", dir=directory)
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)            # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return os.path.getsize(path)


def _dataclass_from(cls, fields: dict):
    # tuples arrive back from JSON as lists; restore hashable field types
    kw = {}
    for f in dataclasses.fields(cls):
        if f.name not in fields:
            continue                     # saved by an older minor config: skip
        v = fields[f.name]
        kw[f.name] = tuple(v) if isinstance(v, list) else v
    return cls(**kw)


def load_artifact(path: str) -> LoadedArtifact:
    """Read a packed artifact back, verifying magic, version, and every
    leaf's SHA-256. Raises :class:`ArtifactError` (with the reason) on a
    truncated/corrupt file, a version this code does not speak, or any
    checksum mismatch — never returns partially-loaded parameters."""
    try:
        file_bytes = os.path.getsize(path)
        with np.load(path, allow_pickle=False) as z:
            if "__manifest__" not in z.files:
                raise ArtifactError(
                    f"{path}: no __manifest__ — not a packed artifact")
            manifest = json.loads(
                z["__manifest__"].tobytes().decode("utf-8"))
            arrays = {k: z[k] for k in z.files if k != "__manifest__"}
    except ArtifactError:
        raise
    except (OSError, zipfile.BadZipFile, ValueError, KeyError) as e:
        raise ArtifactError(f"{path}: unreadable artifact "
                            f"(truncated or corrupt): {e}") from e

    if manifest.get("magic") != ARTIFACT_MAGIC:
        raise ArtifactError(f"{path}: bad magic {manifest.get('magic')!r} "
                            f"(expected {ARTIFACT_MAGIC!r})")
    version = manifest.get("version")
    if version != ARTIFACT_VERSION:
        raise ArtifactError(
            f"{path}: artifact version {version!r} != supported "
            f"{ARTIFACT_VERSION} — re-export the artifact with this "
            "code (the format is not forward/backward compatible)")

    qparams: QuantizedParams = {}
    for name, leaf in manifest["leaves"].items():
        key = f"a/{name}" if leaf["kind"] == "array" else f"q/{name}/data"
        if key not in arrays:
            raise ArtifactError(f"{path}: missing payload for leaf "
                                f"{name!r} ({key})")
        data = arrays[key]
        if _sha256(data) != leaf["sha256"]:
            raise ArtifactError(f"{path}: checksum mismatch on {name!r} "
                                "— artifact is corrupt")
        # device arrays, not numpy: the engine's jitted forwards index
        # these leaves with traced arrays
        if leaf["kind"] == "array":
            qparams[name] = jnp.asarray(data)
            continue
        scale = None
        if leaf["has_scale"]:
            skey = f"q/{name}/scale"
            if skey not in arrays:
                raise ArtifactError(
                    f"{path}: missing scale for leaf {name!r}")
            scale = jnp.asarray(arrays[skey])
        qparams[name] = QTensor(leaf["kind"], jnp.asarray(data), scale)

    model_cfg = _dataclass_from(so3.So3kratesConfig, manifest["model_cfg"])
    serve = _dataclass_from(ServeConfig, manifest["serve_cfg"])
    return LoadedArtifact(qparams=qparams, model_cfg=model_cfg, serve=serve,
                          fp32_bytes=int(manifest["fp32_bytes"]),
                          file_bytes=file_bytes,
                          version_tag=_version_tag(manifest["leaves"]))


def load_engine(path: str, serve: Optional[ServeConfig] = None,
                device=None) -> QuantizedEngine:
    """Cold-start an engine from a packed artifact: deserialize and build
    — no fp32 materialization, no quantization pass. ``serve`` overrides
    the artifact's serving knobs (bucket ladder, path, max_batch), but
    its ``mode`` must match the artifact's — the packed weights *are*
    that mode. ``device`` pins the engine to one JAX device (the
    cluster's per-replica path; see ``QuantizedEngine``)."""
    art = load_artifact(path)
    if serve is None:
        serve = art.serve
    else:
        ensure_mode_matches(art.serve.mode, serve.mode)
    return QuantizedEngine.from_quantized(art.model_cfg, art.qparams, serve,
                                          fp32_nbytes=art.fp32_bytes,
                                          device=device,
                                          artifact_version=art.version_tag)
