"""Open/closed-loop traffic generation and replay for the online server.

Traffic model: **Poisson arrivals** (exponential inter-arrival gaps at a
configured rate) over a **mixed molecule-size distribution** — weighted
size classes, each a uniform ``[min_atoms, max_atoms]`` range — so a run
exercises several buckets of the ladder at once, exactly the regime
dynamic micro-batching exists for. Generation is pure and seeded: the
same ``TrafficConfig`` yields the identical request sequence for every
serving strategy under comparison.

Two arrival shapes:

* :func:`make_traffic` — constant-rate Poisson (the classic load point);
* :func:`make_step_traffic` — a **step ramp**: a piecewise-constant rate
  schedule (:class:`RateStage` list), still Poisson within each stage
  (exponential memorylessness makes restarting the clock at each stage
  boundary exact). This is how overload/recovery scenarios are scripted
  reproducibly — e.g. cruise below capacity, burst far above it, then
  recover — and is shared by ``benchmarks/server_bench.py`` and
  ``benchmarks/cluster_bench.py``.

Two drivers:

* :func:`run_open_loop` — arrivals fire on the wall clock regardless of
  completions (load *offered*, not admitted). Latency is measured from
  each request's **scheduled** arrival, so a driver lagging under
  overload cannot hide queueing delay (no coordinated omission). A
  target shedding load (``SchedulerOverloaded`` from bounded admission —
  single scheduler or cluster pool alike) is recorded per request, not
  treated as a failure. This is the headline mode of the benches.
* :func:`run_closed_loop` — ``concurrency`` clients each keep exactly
  one request in flight (submit, wait, repeat): the sustainable-
  throughput probe, load adapts to the server.

Both return a :class:`TrafficResult` carrying per-request latencies and
the scheduler's flush/queue telemetry, summarized via
``repro.server.stats.latency_summary``; :func:`stage_summaries` splits
an open-loop result back into its ramp stages.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.bucketing import Graph, random_graph
from repro.server.scheduler import SchedulerOverloaded
from repro.server.stats import latency_summary

__all__ = ["SizeClass", "TrafficConfig", "TrafficResult", "RateStage",
           "make_traffic", "make_step_traffic", "stage_summaries",
           "run_open_loop", "run_closed_loop", "calibrate_service_time",
           "draw_graphs"]


@dataclasses.dataclass(frozen=True)
class SizeClass:
    """One component of the molecule-size mixture."""
    min_atoms: int
    max_atoms: int
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """A reproducible request stream."""
    rate_rps: float                     # offered load (open loop)
    n_requests: int
    size_mix: Tuple[SizeClass, ...] = (SizeClass(6, 16, 0.5),
                                       SizeClass(17, 32, 0.5))
    n_species: int = 20
    density: Optional[float] = 0.1      # atoms/A^3 (None = dense cloud)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class RateStage:
    """One step of a piecewise-constant offered-load schedule."""
    rate_rps: float
    duration_s: float


def draw_graphs(rng: np.random.Generator, n: int,
                size_mix: Sequence[SizeClass], n_species: int,
                density: Optional[float]) -> List[Graph]:
    """n molecules from the weighted size mixture — the single recipe
    behind both arrival generators, so a constant-rate stream and a step
    ramp with the same seed draw from the same molecule distribution."""
    weights = np.asarray([c.weight for c in size_mix], np.float64)
    classes = rng.choice(len(size_mix), size=n, p=weights / weights.sum())
    out = []
    for ci in classes:
        c = size_mix[ci]
        n_atoms = int(rng.integers(c.min_atoms, c.max_atoms + 1))
        out.append(random_graph(rng, n_atoms, n_species, density))
    return out


def make_traffic(cfg: TrafficConfig) -> List[Tuple[float, Graph]]:
    """Seeded (arrival_time_s, Graph) list: Poisson arrivals at
    ``rate_rps`` starting at t=0, sizes drawn from the weighted mixture,
    molecules from the same ``random_graph`` recipe the serving benches
    use."""
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.rate_rps, size=cfg.n_requests)
    arrivals = np.cumsum(gaps)
    graphs = draw_graphs(rng, cfg.n_requests, cfg.size_mix, cfg.n_species,
                         cfg.density)
    return [(float(t), g) for t, g in zip(arrivals, graphs)]


def make_step_traffic(stages: Sequence[RateStage],
                      size_mix: Tuple[SizeClass, ...] = TrafficConfig.size_mix,
                      n_species: int = 20,
                      density: Optional[float] = 0.1,
                      seed: int = 0) -> List[Tuple[float, Graph]]:
    """Seeded step-ramp arrivals: Poisson within each stage at that
    stage's rate. The request count is whatever the schedule produces
    (stochastic but fully determined by the seed), so identical replays
    across serving strategies — the way overload and recovery scenarios
    stay reproducible. Restarting the exponential clock at each stage
    boundary is exact (memorylessness), not an approximation."""
    if not stages:
        raise ValueError("need at least one RateStage")
    rng = np.random.default_rng(seed)
    arrivals: List[float] = []
    t_start = 0.0
    for st in stages:
        if st.rate_rps <= 0 or st.duration_s <= 0:
            raise ValueError("RateStage rate and duration must be > 0")
        t = t_start
        t_end = t_start + st.duration_s
        while True:
            t += rng.exponential(1.0 / st.rate_rps)
            if t >= t_end:
                break
            arrivals.append(t)
        t_start = t_end
    graphs = draw_graphs(rng, len(arrivals), size_mix, n_species, density)
    return list(zip(arrivals, graphs))


@dataclasses.dataclass(frozen=True)
class TrafficResult:
    """One driver run: per-request timings + scheduler telemetry."""
    latencies_s: np.ndarray       # per completed request, submission order
    span_s: float                 # first arrival -> last completion
    offered_rps: Optional[float]  # open loop: the configured rate
    submit_lag_p99_ms: float      # driver lateness (diagnostic, open loop)
    scheduler_stats: Dict[str, object]
    # scheduled arrival times of the completed requests (aligned with
    # latencies_s) and of the shed ones — lets stage_summaries() split a
    # ramp run back into its stages
    arrivals_s: Optional[np.ndarray] = None
    shed_arrivals_s: Optional[np.ndarray] = None

    @property
    def n_shed(self) -> int:
        return 0 if self.shed_arrivals_s is None else len(self.shed_arrivals_s)

    def summary(self) -> Dict[str, float]:
        out = latency_summary(self.latencies_s, self.span_s)
        out["n_shed"] = self.n_shed
        return out


def stage_summaries(result: TrafficResult,
                    stages: Sequence[RateStage]) -> List[Dict[str, float]]:
    """Per-stage latency/throughput summaries of an open-loop step-ramp
    replay: each completed request is attributed to the stage its
    *scheduled arrival* fell in (so queue carry-over into a recovery
    stage shows up as that stage's tail latency — exactly the overload
    signature the ramp exists to expose)."""
    if result.arrivals_s is None:
        raise ValueError("result carries no arrival times "
                         "(closed-loop results cannot be staged)")
    arr = np.asarray(result.arrivals_s)
    shed = (np.asarray(result.shed_arrivals_s)
            if result.shed_arrivals_s is not None else np.empty(0))
    out = []
    lo = 0.0
    for st in stages:
        hi = lo + st.duration_s
        sel = (arr >= lo) & (arr < hi)
        row: Dict[str, float] = {
            "rate_rps": st.rate_rps, "duration_s": st.duration_s,
            "n_offered": int(sel.sum()
                             + ((shed >= lo) & (shed < hi)).sum()),
            "n_shed": int(((shed >= lo) & (shed < hi)).sum()),
        }
        if sel.any():
            row.update(latency_summary(result.latencies_s[sel],
                                       span_s=st.duration_s))
        out.append(row)
        lo = hi
    return out


def calibrate_service_time(engine, buckets: Optional[Sequence[int]] = None,
                           repeats: int = 7, seed: int = 17) -> float:
    """Expected seconds for one single-molecule request under a mixed
    size distribution (the per-request server's unit of work): the mean
    over one representative molecule per bucket of the engine's ladder
    — calibrating on the small bucket alone would overstate sequential
    capacity and make every offered-load multiple secretly an overload.
    Shared by ``server_bench`` and ``cluster_bench`` so their load
    factors mean the same thing."""
    import statistics
    rng = np.random.default_rng(seed)
    if buckets is None:
        buckets = engine.serve.bucket_sizes
    per_bucket = []
    for cap in buckets:
        n = max(6, (3 * cap) // 4)
        g = random_graph(rng, n, engine.model_cfg.n_species, density=0.1)
        engine.infer_batch([g])     # ensure warm
        times = []
        for _ in range(repeats):
            t0 = time.monotonic()
            engine.infer_batch([g])
            times.append(time.monotonic() - t0)
        per_bucket.append(statistics.median(times))
    return statistics.mean(per_bucket)


def run_open_loop(scheduler, traffic: Sequence[Tuple[float, Graph]],
                  rate_rps: Optional[float] = None,
                  result_timeout: Optional[float] = None) -> TrafficResult:
    """Replay ``traffic`` against the wall clock: each request is
    submitted at its scheduled arrival time (sleeping in between),
    completions are awaited afterwards. Latency for request i is
    ``t_complete_i - t_scheduled_arrival_i``. ``scheduler`` is anything
    with ``submit(graph) -> RequestHandle`` and ``stats()`` — the
    single-engine ``MicroBatchScheduler`` or a ``repro.cluster`` pool.
    Requests shed by bounded admission (``SchedulerOverloaded``) are
    counted, not raised: under deliberate overload shedding is the
    correct server behavior and the replay must keep offering load.
    ``result_timeout`` bounds each completion wait — pass one in
    harnesses whose whole point is proving no request is ever lost, so
    a leaked handle fails loudly (TimeoutError) instead of hanging the
    run."""
    handles: List[Tuple[float, object]] = []
    shed: List[float] = []
    lags = []
    t0 = time.monotonic()
    for t_arr, g in traffic:
        delay = t0 + t_arr - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        lags.append(time.monotonic() - (t0 + t_arr))
        try:
            handles.append((t_arr, scheduler.submit(g)))
        except SchedulerOverloaded:
            shed.append(t_arr)
    for _, h in handles:
        h.result(timeout=result_timeout)
    t_end = max((h.t_done for _, h in handles), default=t0)
    lat = np.asarray([h.t_done - (t0 + t_arr) for t_arr, h in handles])
    return TrafficResult(
        latencies_s=lat,
        span_s=t_end - (t0 + traffic[0][0]),
        offered_rps=rate_rps,
        submit_lag_p99_ms=float(np.percentile(lags, 99) * 1e3),
        scheduler_stats=scheduler.stats(),
        arrivals_s=np.asarray([t_arr for t_arr, _ in handles]),
        shed_arrivals_s=np.asarray(shed))


def run_closed_loop(scheduler, graphs: Sequence[Graph],
                    concurrency: int = 4) -> TrafficResult:
    """``concurrency`` synchronous clients round-robin the request list,
    each keeping one request in flight. Latency is submit -> completion.
    A client exception (shed from bounded admission, a failover error)
    is re-raised here after all clients stop — never swallowed into a
    dead thread that silently under-reports samples."""
    chunks = [list(graphs[i::concurrency]) for i in range(concurrency)]
    lat_chunks: List[List[float]] = [[] for _ in range(concurrency)]
    done_t = [0.0] * concurrency
    errors: List[BaseException] = []

    def client(ci: int):
        try:
            for g in chunks[ci]:
                h = scheduler.submit(g)
                h.result()
                lat_chunks[ci].append(h.latency_s)
        except BaseException as e:
            errors.append(e)
        finally:
            done_t[ci] = time.monotonic()

    t0 = time.monotonic()
    threads = [threading.Thread(target=client, args=(ci,), daemon=True)
               for ci in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    lat = np.asarray([x for c in lat_chunks for x in c])
    return TrafficResult(
        latencies_s=lat, span_s=max(done_t) - t0, offered_rps=None,
        submit_lag_p99_ms=0.0, scheduler_stats=scheduler.stats())
