"""Open/closed-loop traffic generation and replay for the online server.

Traffic model: **Poisson arrivals** (exponential inter-arrival gaps at a
configured rate) over a **mixed molecule-size distribution** — weighted
size classes, each a uniform ``[min_atoms, max_atoms]`` range — so a run
exercises several buckets of the ladder at once, exactly the regime
dynamic micro-batching exists for. Generation is pure and seeded: the
same ``TrafficConfig`` yields the identical request sequence for every
serving strategy under comparison.

Two drivers:

* :func:`run_open_loop` — arrivals fire on the wall clock regardless of
  completions (load *offered*, not admitted). Latency is measured from
  each request's **scheduled** arrival, so a driver lagging under
  overload cannot hide queueing delay (no coordinated omission). This is
  the headline mode of ``benchmarks/server_bench.py``.
* :func:`run_closed_loop` — ``concurrency`` clients each keep exactly
  one request in flight (submit, wait, repeat): the sustainable-
  throughput probe, load adapts to the server.

Both return a :class:`TrafficResult` carrying per-request latencies and
the scheduler's flush/queue telemetry, summarized via
``repro.server.stats.latency_summary``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.bucketing import Graph, random_graph
from repro.server.scheduler import MicroBatchScheduler
from repro.server.stats import latency_summary

__all__ = ["SizeClass", "TrafficConfig", "TrafficResult", "make_traffic",
           "run_open_loop", "run_closed_loop"]


@dataclasses.dataclass(frozen=True)
class SizeClass:
    """One component of the molecule-size mixture."""
    min_atoms: int
    max_atoms: int
    weight: float = 1.0


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """A reproducible request stream."""
    rate_rps: float                     # offered load (open loop)
    n_requests: int
    size_mix: Tuple[SizeClass, ...] = (SizeClass(6, 16, 0.5),
                                       SizeClass(17, 32, 0.5))
    n_species: int = 20
    density: Optional[float] = 0.1      # atoms/A^3 (None = dense cloud)
    seed: int = 0


def make_traffic(cfg: TrafficConfig) -> List[Tuple[float, Graph]]:
    """Seeded (arrival_time_s, Graph) list: Poisson arrivals at
    ``rate_rps`` starting at t=0, sizes drawn from the weighted mixture,
    molecules from the same ``random_graph`` recipe the serving benches
    use."""
    rng = np.random.default_rng(cfg.seed)
    gaps = rng.exponential(1.0 / cfg.rate_rps, size=cfg.n_requests)
    arrivals = np.cumsum(gaps)
    weights = np.asarray([c.weight for c in cfg.size_mix], np.float64)
    classes = rng.choice(len(cfg.size_mix), size=cfg.n_requests,
                         p=weights / weights.sum())
    out = []
    for t, ci in zip(arrivals, classes):
        c = cfg.size_mix[ci]
        n = int(rng.integers(c.min_atoms, c.max_atoms + 1))
        out.append((float(t),
                    random_graph(rng, n, cfg.n_species, cfg.density)))
    return out


@dataclasses.dataclass(frozen=True)
class TrafficResult:
    """One driver run: per-request timings + scheduler telemetry."""
    latencies_s: np.ndarray       # per request, in submission order
    span_s: float                 # first arrival -> last completion
    offered_rps: Optional[float]  # open loop: the configured rate
    submit_lag_p99_ms: float      # driver lateness (diagnostic, open loop)
    scheduler_stats: Dict[str, object]

    def summary(self) -> Dict[str, float]:
        return latency_summary(self.latencies_s, self.span_s)


def run_open_loop(scheduler: MicroBatchScheduler,
                  traffic: Sequence[Tuple[float, Graph]],
                  rate_rps: Optional[float] = None) -> TrafficResult:
    """Replay ``traffic`` against the wall clock: each request is
    submitted at its scheduled arrival time (sleeping in between),
    completions are awaited afterwards. Latency for request i is
    ``t_complete_i - t_scheduled_arrival_i``."""
    handles = []
    lags = []
    t0 = time.monotonic()
    for t_arr, g in traffic:
        delay = t0 + t_arr - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        lags.append(time.monotonic() - (t0 + t_arr))
        handles.append(scheduler.submit(g))
    for h in handles:
        h.result()
    t_end = max(h.t_done for h in handles)
    lat = np.asarray([h.t_done - (t0 + t_arr)
                      for h, (t_arr, _) in zip(handles, traffic)])
    return TrafficResult(
        latencies_s=lat, span_s=t_end - (t0 + traffic[0][0]),
        offered_rps=rate_rps,
        submit_lag_p99_ms=float(np.percentile(lags, 99) * 1e3),
        scheduler_stats=scheduler.stats())


def run_closed_loop(scheduler: MicroBatchScheduler,
                    graphs: Sequence[Graph],
                    concurrency: int = 4) -> TrafficResult:
    """``concurrency`` synchronous clients round-robin the request list,
    each keeping one request in flight. Latency is submit -> completion."""
    chunks = [list(graphs[i::concurrency]) for i in range(concurrency)]
    lat_chunks: List[List[float]] = [[] for _ in range(concurrency)]
    done_t = [0.0] * concurrency

    def client(ci: int):
        for g in chunks[ci]:
            h = scheduler.submit(g)
            h.result()
            lat_chunks[ci].append(h.latency_s)
        done_t[ci] = time.monotonic()

    t0 = time.monotonic()
    threads = [threading.Thread(target=client, args=(ci,), daemon=True)
               for ci in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    lat = np.asarray([x for c in lat_chunks for x in c])
    return TrafficResult(
        latencies_s=lat, span_s=max(done_t) - t0, offered_rps=None,
        submit_lag_p99_ms=0.0, scheduler_stats=scheduler.stats())
