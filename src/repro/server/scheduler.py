"""Dynamic micro-batching scheduler: online requests -> engine batches.

``QuantizedEngine.infer_batch`` is synchronous: the caller supplies a
whole batch and waits. Online traffic doesn't look like that — requests
arrive one at a time, and the serving system must *form* batches under a
latency budget. This module holds the two pieces that do it:

* :class:`BatchQueue` — the pure **queueing/flush policy**, with no
  thread and no engine: per-shape-class admission queues over the
  engine's bucket ladder, the two flush triggers (full / deadline), the
  anti-starvation flush ordering, and drain. It is deliberately
  standalone so the same policy drives both the single-engine
  :class:`MicroBatchScheduler` below and every replica of the
  multi-engine cluster (``repro.cluster`` — a cluster replica is this
  policy plus its own worker thread and device-pinned engine; the
  single-engine scheduler is the ``n_replicas=1`` degenerate case).
* :class:`MicroBatchScheduler` — one worker thread owning one engine,
  fed by one :class:`BatchQueue`.

Policy semantics:

* **per-shape-class admission queues** — each arriving molecule is
  assigned its bucket (same ``assign_bucket`` as ``infer_batch``) and
  queued with peers of the same shape class, so every flush is a single
  compiled dispatch (one bucket, one batch class);
* **two flush triggers** — a queue flushes when it holds ``max_batch``
  requests ("full": the batch cannot grow further) or when its oldest
  request has waited ``deadline_ms`` ("deadline": latency budget spent
  on batching; ship what we have). ``max_batch=1, deadline_ms=0``
  degenerates to per-request serving — the benchmark baseline (with
  ``max_batch > 1`` a zero deadline still flushes whatever queued
  during the previous dispatch as one batch);
* **bounded admission** — with ``max_queue`` set, ``submit`` sheds load
  with :class:`SchedulerOverloaded` (carrying a ``retry_after_s`` hint)
  instead of letting the queue grow without bound; ``submit`` after
  ``close()`` raises :class:`SchedulerClosed` — a request is either
  admitted (and will resolve) or refused loudly, never silently hung;
* **request -> result identity** — ``submit`` returns a
  :class:`RequestHandle`; flushes from different buckets complete out of
  submission order, but each handle resolves to exactly its own
  molecule's result (pinned to <= 1e-6 against a direct
  ``infer_batch([g])`` in ``tests/test_server.py``);
* **no steady-state compilation** — the scheduler calls
  ``engine.warmup()`` at start by default; every shape a flush can
  produce is in the engine's admissible set, so traffic never waits on
  XLA.

One worker thread owns the engine (JAX dispatch is serialized anyway on
a single device; batching, not thread parallelism, is where the
throughput comes from — until the cluster adds devices). ``submit`` is
thread-safe and cheap: it appends to a queue and signals the worker.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.guardrails import GuardrailViolation
from repro.obs.metrics import REGISTRY
from repro.obs.trace import TRACER
from repro.serving.bucketing import BucketSpec, Graph, assign_bucket
from repro.serving.engine import QuantizedEngine, MoleculeResult
from repro.server.stats import FlushRecord, flush_summary

__all__ = ["SchedulerConfig", "SchedulerClosed", "SchedulerOverloaded",
           "RequestTimeout", "RequestHandle", "BatchQueue",
           "MicroBatchScheduler"]


class SchedulerClosed(RuntimeError):
    """``submit`` was called on a closed scheduler (or a dead cluster
    replica): the request was NOT admitted and no handle exists — callers
    must not wait on anything. Raised instead of silently hanging."""


class RequestTimeout(TimeoutError):
    """``RequestHandle.result(timeout_s=...)`` expired before the
    request resolved. Subclasses :class:`TimeoutError` so callers that
    caught the old builtin keep working; typed so the session manager
    and the pool watchdog can tell a deadline miss (request may still
    complete — retrying a pure chunk is safe) from an engine error."""


class SchedulerOverloaded(RuntimeError):
    """Bounded admission refused a request: every eligible queue is at
    ``max_queue``. ``retry_after_s`` is a hint — roughly how long the
    backlog needs to drain one batch — for client backoff."""

    def __init__(self, msg: str, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Batch-formation knobs (the engine's ServeConfig stays in charge of
    shapes, paths, and kernels)."""
    max_batch: int = 8        # flush a queue at this many requests
    deadline_ms: float = 20.0  # max batching wait for the oldest request
    warmup: bool = True       # pre-compile all shapes before serving
    # bounded admission: total queued requests before submit sheds with
    # SchedulerOverloaded (None = unbounded, the pre-cluster behavior)
    max_queue: Optional[int] = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.deadline_ms < 0:
            raise ValueError("deadline_ms must be >= 0")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")


class RequestHandle:
    """A pending request's future. ``result()`` blocks until the flush
    containing this molecule completes, then returns its
    :class:`MoleculeResult` (or re-raises the engine's exception).

    ``replica_id`` is set when the request resolves (0 for the
    single-engine scheduler; the serving replica's id in a cluster —
    after failover this is the survivor that actually completed it).
    ``n_requeues`` counts cluster failover requeues (0 outside clusters).

    ``trace`` is the request's :class:`repro.obs.trace.RequestTrace`
    (``None`` when tracing is disabled — the default). It is minted here
    so the root span starts exactly at ``t_submit``, and finished in
    ``_resolve`` at exactly ``t_done``, whichever path (scheduler,
    cluster replica, failover survivor) resolves the handle.
    """

    __slots__ = ("graph", "t_submit", "t_done", "bucket_capacity",
                 "replica_id", "n_requeues", "escalations", "trace",
                 "_event", "_result", "_error")

    _trace_kind = "request"  # ChunkHandle overrides

    def __init__(self, graph: Graph, t_submit: float,
                 bucket_capacity: int = 0):
        self.graph = graph
        self.t_submit = t_submit
        self.t_done: Optional[float] = None
        self.bucket_capacity = bucket_capacity
        self.replica_id: Optional[int] = None
        self.n_requeues = 0
        # precision-tier escalation trail (repro.guardrails
        # EscalationRecords, appended by ClusterPool when a flagged
        # result is re-run one tier up; stamped into the final result)
        self.escalations: list = []
        self.trace = TRACER.start_request(kind=type(self)._trace_kind,
                                          t0=t_submit)
        self._event = threading.Event()
        self._result: Optional[MoleculeResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def _reject(self, exc: BaseException) -> None:
        """Submit-path rejection (oversize / shed / closed): the handle
        is never returned to the caller, so finish its trace here —
        rejections stay observable and no trace is left unfinished."""
        if self.trace is not None:
            self.trace.finish(status="rejected",
                              error=type(exc).__name__)

    def result(self, timeout: Optional[float] = None,
               timeout_s: Optional[float] = None) -> MoleculeResult:
        """Block for the result. ``timeout_s`` (alias of the older
        ``timeout``; it wins when both are given) bounds the wait and
        raises a typed :class:`RequestTimeout` instead of blocking
        forever — the request itself stays in flight and may still
        resolve (a pool watchdog recovering a stalled replica resolves
        it later; first resolution wins)."""
        t = timeout_s if timeout_s is not None else timeout
        if not self._event.wait(t):
            raise RequestTimeout(
                f"request not completed within {t}s (submitted "
                f"{time.monotonic() - self.t_submit:.3f}s ago)")
        if self._error is not None:
            raise self._error
        return self._result  # type: ignore[return-value]

    @property
    def latency_s(self) -> float:
        """Submit -> completion wall clock (queue wait + batching wait +
        service). Only valid once ``done()``."""
        if self.t_done is None:
            raise RuntimeError("request not completed")
        return self.t_done - self.t_submit

    def _resolve(self, result=None, error=None, replica_id=None):
        # first resolution wins: after a watchdog expropriates a stalled
        # replica and requeues its in-flight work, both the survivor and
        # the (eventually waking) stuck worker resolve the same handle —
        # the late one must be a no-op, not a result swap under a reader
        if self._event.is_set():
            return
        self._result, self._error = result, error
        if replica_id is not None:
            self.replica_id = replica_id
        now = time.monotonic()
        self.t_done = now
        if self.trace is not None:
            # same instant as t_done: the trace's span durations sum
            # exactly to latency_s (the tiling invariant, repro.obs.trace)
            self.trace.finish(
                now,
                status="error" if error is not None else "ok",
                error=type(error).__name__ if error is not None else None,
                replica_id=self.replica_id,
                bucket=self.bucket_capacity,
                n_requeues=self.n_requeues,
                n_escalations=len(self.escalations))
        if REGISTRY.enabled:
            # per-request e2e latency, windowed-p99 SLO feed; labelled
            # by kind so chunk runtimes never pollute the request p99
            REGISTRY.histogram(
                "serve_request_latency_seconds",
                kind=type(self)._trace_kind,
                bucket=str(self.bucket_capacity)).observe(
                now - self.t_submit)
        self._event.set()


class BatchQueue:
    """Per-shape-class admission queues + the flush policy, with no
    thread of its own.

    This is the piece shared between the single-engine
    :class:`MicroBatchScheduler` and every cluster replica
    (``repro.cluster.replica``): both own one ``BatchQueue``, hold their
    own lock around every call (nothing here is synchronized), and run
    the identical policy — what queues exist, when one flushes, which
    flushes first, and what draining means.
    """

    def __init__(self, buckets: List[BucketSpec], config: SchedulerConfig):
        self.config = config
        self._buckets = list(buckets)
        self._queues: Dict[int, Deque[RequestHandle]] = {
            b.capacity: deque() for b in self._buckets}

    def bucket_of(self, graph: Graph) -> BucketSpec:
        """Shape class a graph will be queued (and dispatched) under.
        Raises like ``infer_batch`` for molecules off the ladder."""
        return assign_bucket(graph.n_atoms, self._buckets)

    def append(self, handle: RequestHandle) -> None:
        """Admit one handle to its shape class's queue. The handle's
        ``bucket_capacity`` must already be set (``bucket_of``)."""
        self._queues[handle.bucket_capacity].append(handle)

    def depth(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def depth_of(self, capacity: int) -> int:
        return len(self._queues[capacity])

    def is_full(self) -> bool:
        mq = self.config.max_queue
        return mq is not None and self.depth() >= mq

    def oldest_deadline(self) -> Optional[float]:
        """Monotonic time at which the oldest queued request's batching
        budget expires (None when all queues are empty)."""
        t = None
        for q in self._queues.values():
            if q:
                cand = q[0].t_submit + self.config.deadline_ms * 1e-3
                t = cand if t is None else min(t, cand)
        return t

    def pick_flush(self, now: float, drain: bool
                   ) -> Optional[Tuple[int, List[RequestHandle], str]]:
        """Choose (capacity, handles, reason) for the next flush, or None
        when no trigger has fired. Among all *triggered* queues (full, or
        head's deadline expired) the one whose head request is oldest
        goes first — a bucket whose queue refills to max_batch faster
        than flushes complete must not starve deadline-expired requests
        in other buckets. With ``drain`` the oldest non-empty queue
        flushes unconditionally (close()/failover teardown)."""
        best = None          # (head_t_submit, cap, reason)
        oldest = None        # (head_t_submit, cap) over non-empty queues
        deadline_s = self.config.deadline_ms * 1e-3
        for cap, q in self._queues.items():
            if not q:
                continue
            head_t = q[0].t_submit
            if oldest is None or head_t < oldest[0]:
                oldest = (head_t, cap)
            if len(q) >= self.config.max_batch:
                reason = "full"
            elif now >= head_t + deadline_s:
                reason = "deadline"
            else:
                continue
            if best is None or head_t < best[0]:
                best = (head_t, cap, reason)
        if best is not None:
            _, cap, reason = best
            return cap, self._pop(cap), reason
        if drain and oldest is not None:
            return oldest[1], self._pop(oldest[1]), "drain"
        return None

    def _pop(self, cap: int) -> List[RequestHandle]:
        q = self._queues[cap]
        return [q.popleft() for _ in range(min(len(q),
                                               self.config.max_batch))]

    def drain_all(self) -> List[RequestHandle]:
        """Remove and return every queued handle (failover: the pool
        requeues them onto surviving replicas)."""
        out: List[RequestHandle] = []
        for q in self._queues.values():
            out.extend(q)
            q.clear()
        return out


class MicroBatchScheduler:
    """Online request scheduler over a :class:`QuantizedEngine`.

    Use as a context manager (or call ``close()``), so the worker thread
    drains and exits::

        with MicroBatchScheduler(engine, SchedulerConfig()) as sched:
            handles = [sched.submit(g) for g in graphs]
            results = [h.result() for h in handles]
    """

    def __init__(self, engine: QuantizedEngine,
                 config: SchedulerConfig = SchedulerConfig()):
        self.engine = engine
        self.config = config
        if config.max_batch > engine.serve.max_batch:
            raise ValueError(
                f"SchedulerConfig.max_batch {config.max_batch} exceeds "
                f"ServeConfig.max_batch {engine.serve.max_batch}: flushes "
                "must fit one engine batch")
        self._queue = BatchQueue(engine.serve.buckets(), config)
        self._lock = threading.Condition()
        self._open = True
        self._flushes: List[FlushRecord] = []
        self._n_submitted = 0
        self._n_completed = 0
        self._n_shed = 0
        self._n_guard_flagged = 0
        self._service_ema: Optional[float] = None
        # dual-write into the process-wide metrics plane (repro.obs):
        # the per-instance counters above stay the thin stats() view,
        # the registry carries fleet-lifetime labelled totals
        self._m_requests = {
            k: REGISTRY.counter("serve_requests_total",
                                surface="scheduler", event=k)
            for k in ("submitted", "completed", "shed", "guard_flagged")}
        self._m_wait = REGISTRY.histogram("serve_queue_wait_seconds",
                                          surface="scheduler")
        self._m_service = REGISTRY.histogram("serve_flush_seconds",
                                             surface="scheduler")
        self.warmup_s = engine.warmup() if config.warmup else 0.0
        self._worker = threading.Thread(
            target=self._serve_loop, name="microbatch-scheduler", daemon=True)
        self._worker.start()

    # -- client side --------------------------------------------------------

    def submit(self, graph: Graph) -> RequestHandle:
        """Admit one molecule. Raises like ``infer_batch`` for molecules
        larger than the bucket ladder; :class:`SchedulerClosed` after
        ``close()``; :class:`SchedulerOverloaded` when bounded admission
        (``max_queue``) sheds the request."""
        handle = RequestHandle(graph, time.monotonic())
        try:
            with self._lock:
                # bucket assignment under the lock keeps oversize
                # rejection ordered with close(); it is a few
                # comparisons, not work
                handle.bucket_capacity = (
                    self._queue.bucket_of(graph).capacity)
                if not self._open:
                    raise SchedulerClosed(
                        "scheduler is closed: request not admitted")
                if self._queue.is_full():
                    self._n_shed += 1
                    self._m_requests["shed"].inc()
                    retry = self._retry_after_locked()
                    raise SchedulerOverloaded(
                        f"admission queue at max_queue="
                        f"{self.config.max_queue}: request shed "
                        f"(retry in ~{retry:.3f}s)", retry)
                self._queue.append(handle)
                self._n_submitted += 1
                self._m_requests["submitted"].inc()
                self._lock.notify()
        except BaseException as e:
            handle._reject(e)
            raise
        if handle.trace is not None:
            handle.trace.set_attr("bucket", handle.bucket_capacity)
        return handle

    def _retry_after_locked(self) -> float:
        """Backoff hint: roughly one flush's service time, or the
        batching deadline when nothing has been served yet."""
        if self._service_ema is not None:
            return self._service_ema
        return max(self.config.deadline_ms * 1e-3, 0.01)

    def close(self):
        """Stop admitting, drain every queue, join the worker."""
        with self._lock:
            if not self._open:
                return
            self._open = False
            self._lock.notify()
        self._worker.join()

    def __enter__(self) -> "MicroBatchScheduler":
        return self

    def __exit__(self, *exc):
        self.close()

    # -- telemetry ----------------------------------------------------------

    def queue_depth(self) -> int:
        with self._lock:
            return self._queue.depth()

    def stats(self) -> Dict[str, object]:
        """Flush telemetry (batch-size distribution = achieved bucket
        occupancy, flush reasons, queue depths) + request counters and
        the engine's dispatch counters."""
        with self._lock:
            flushes = list(self._flushes)
            out = {"n_submitted": self._n_submitted,
                   "n_completed": self._n_completed,
                   "n_shed": self._n_shed,
                   "n_guard_flagged": self._n_guard_flagged,
                   "warmup_s": self.warmup_s}
        out.update(flush_summary(flushes))
        out["engine_dispatch"] = self.engine.stats_snapshot()
        return out

    # -- worker side --------------------------------------------------------

    def _serve_loop(self):
        while True:
            with self._lock:
                while True:
                    now = time.monotonic()
                    depth = self._queue.depth()
                    picked = self._queue.pick_flush(now, drain=not self._open)
                    if picked is not None:
                        break
                    if not self._open and depth == 0:
                        return
                    deadline = self._queue.oldest_deadline()
                    self._lock.wait(
                        None if deadline is None else max(deadline - now, 0))
                cap, handles, reason = picked
            # engine work runs outside the lock: submit stays non-blocking
            wait_s = time.monotonic() - handles[0].t_submit
            t0 = time.monotonic()
            for h in handles:
                if h.trace is not None:
                    # close the queue segment, open serve, same instant
                    h.trace.begin("serve", t0, replica=0, bucket=cap,
                                  flush_reason=reason)
            try:
                # on_flag="mark": a poison molecule must fail *its own*
                # handle with a typed error, not its batch peers — the
                # per-handle triage happens below
                results = self.engine.infer_batch(
                    [h.graph for h in handles], on_flag="mark")
            except BaseException as e:  # propagate to every waiting client
                for h in handles:
                    h._resolve(error=e, replica_id=0)
                continue
            service_s = time.monotonic() - t0
            # bookkeeping strictly before resolving: a client returning
            # from result() must already see this flush in stats()
            n_flagged = sum(1 for r in results if r.flags)
            trace_ids = tuple(h.trace.trace_id for h in handles
                              if h.trace is not None)
            # stub engines in tests may not expose the profiling hook
            bd = getattr(self.engine, "last_infer_breakdown", None) or {}
            with self._lock:
                self._n_completed += len(handles)
                self._n_guard_flagged += n_flagged
                self._service_ema = (service_s if self._service_ema is None
                                     else 0.8 * self._service_ema
                                     + 0.2 * service_s)
                self._flushes.append(FlushRecord(
                    capacity=cap, n_requests=len(handles), reason=reason,
                    queue_depth=depth, wait_s=wait_s, service_s=service_s,
                    path=results[0].path, batch_size=results[0].batch_size,
                    replica_id=0, trace_ids=trace_ids,
                    prep_s=bd.get("prep_s", 0.0),
                    dispatch_s=bd.get("dispatch_s", 0.0),
                    sync_s=bd.get("sync_s", 0.0),
                    t_start=t0))
            self._m_requests["completed"].inc(len(handles))
            if n_flagged:
                self._m_requests["guard_flagged"].inc(n_flagged)
            self._m_wait.observe(wait_s)
            self._m_service.observe(service_s)
            REGISTRY.counter("serve_flushes_total", surface="scheduler",
                             reason=reason).inc()
            for h, r in zip(handles, results):
                if h.trace is not None:
                    r = dataclasses.replace(r, trace_id=h.trace.trace_id)
                    for f in r.flags:
                        h.trace.event("guardrail_flag", reason=f.reason,
                                      severity=f.severity)
                # fatal flags (non-finite values) are never delivered:
                # the single-engine scheduler has no higher tier to
                # escalate to, so the handle gets the typed error.
                # Suspect flags ride out annotated in result.flags.
                fatal = next((f for f in r.flags if f.fatal), None)
                if fatal is not None:
                    h._resolve(error=GuardrailViolation(
                        f"guardrail {fatal.reason}: result withheld",
                        reason=fatal.reason, severity=fatal.severity),
                        replica_id=0)
                else:
                    h._resolve(result=r, replica_id=0)
