"""Latency/throughput accounting shared by the scheduler, the traffic
harness, and ``benchmarks/server_bench.py``.

Percentiles are computed over *request* latencies (one sample per
molecule, not per batch) with linear interpolation — the convention the
serving literature reports p50/p95/p99 in. Open-loop latency is measured
from the request's **scheduled arrival time**, not from when the driver
thread actually managed to submit it, so a driver that falls behind under
overload cannot hide queueing delay (coordinated omission).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["latency_summary", "FlushRecord", "flush_summary"]


def latency_summary(latencies_s: Sequence[float],
                    span_s: Optional[float] = None) -> Dict[str, float]:
    """p50/p95/p99/mean/max latency (milliseconds) + throughput over the
    span (requests/s). ``span_s`` is first-arrival -> last-completion;
    when omitted only the latency fields are filled."""
    lat = np.asarray(latencies_s, dtype=np.float64)
    if lat.size == 0:
        raise ValueError("no latency samples")
    out = {
        "n_requests": int(lat.size),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p95_ms": float(np.percentile(lat, 95) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "mean_ms": float(lat.mean() * 1e3),
        "max_ms": float(lat.max() * 1e3),
    }
    if span_s is not None:
        out["span_s"] = float(span_s)
        out["throughput_rps"] = float(lat.size / max(span_s, 1e-9))
    return out


@dataclasses.dataclass(frozen=True)
class FlushRecord:
    """One scheduler flush: which shape class ran, where, and why."""
    capacity: int        # bucket the flushed queue belongs to
    n_requests: int      # real molecules in the flush
    reason: str          # "full" | "deadline" | "drain"
    queue_depth: int     # total requests waiting across all queues, pre-pop
    wait_s: float        # oldest request's queue residence at flush time
    service_s: float     # infer_batch wall clock for the flush
    path: str            # execution path the batch took (dense/sparse)
    batch_size: int = 0  # compiled batch rows (incl. alignment dummies)
    replica_id: int = 0  # replica that served the flush (0: single engine)
    # obs linkage: trace ids of the requests in this flush (empty when
    # tracing is disabled); joins flush telemetry to per-request traces
    trace_ids: tuple = ()
    # per-flush serve-time breakdown from the engine profiling hooks
    # (repro.obs): prep (padding), dispatch (kernel submit), device sync
    prep_s: float = 0.0
    dispatch_s: float = 0.0
    sync_s: float = 0.0
    # monotonic flush start time: places the flush on the fleet
    # timeline (repro.obs.timeline); 0.0 = recorded pre-timeline
    t_start: float = 0.0


def flush_summary(flushes: Sequence[FlushRecord]) -> Dict[str, object]:
    """Aggregate flush telemetry: batch-size distribution (the bucket
    occupancy dynamic batching achieved), flush reasons, queue depths,
    and the per-replica breakdown that verifies cluster routing balance
    (degenerate single-replica schedulers report one entry for id 0)."""
    if not flushes:
        return {"n_flushes": 0}
    sizes = np.asarray([f.n_requests for f in flushes], np.float64)
    depths = np.asarray([f.queue_depth for f in flushes], np.float64)
    reasons: Dict[str, int] = {}
    per_bucket: Dict[int, List[int]] = {}
    per_replica: Dict[int, List[FlushRecord]] = {}
    for f in flushes:
        reasons[f.reason] = reasons.get(f.reason, 0) + 1
        per_bucket.setdefault(f.capacity, []).append(f.n_requests)
        per_replica.setdefault(f.replica_id, []).append(f)
    return {
        "n_flushes": len(flushes),
        "mean_batch": float(sizes.mean()),
        "max_batch": int(sizes.max()),
        "mean_queue_depth": float(depths.mean()),
        "max_queue_depth": int(depths.max()),
        "flush_reasons": reasons,
        "mean_batch_per_bucket": {
            str(cap): float(np.mean(v)) for cap, v in sorted(
                per_bucket.items())},
        "per_replica": {
            str(rid): {
                "n_flushes": len(fs),
                "n_requests": int(sum(f.n_requests for f in fs)),
                "mean_batch": float(np.mean([f.n_requests for f in fs])),
            } for rid, fs in sorted(per_replica.items())},
    }
