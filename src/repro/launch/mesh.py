"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before any jax import, while tests/benches must see
the single real CPU device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "DATA_AXES", "MODEL_AXIS"]

# batch / sequence shard over these; tensor/expert parallel over MODEL_AXIS
DATA_AXES = ("pod", "data")
MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1x1 mesh with the same axis names, for CPU tests of sharded code."""
    return jax.make_mesh((1, 1), ("data", "model"))
