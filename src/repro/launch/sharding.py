"""Partition rules: parameter / optimizer / batch / cache PartitionSpecs.

Scheme (baseline; §Perf iterates from here):
  * DP over ("pod", "data") — batch dims.
  * TP over "model" — Megatron column/row splits: every projection's non-
    d_model dim (heads*head_dim, d_ff, vocab, d_inner, experts) divides 16
    for all assigned archs, so weights shard cleanly.
  * EP: MoE expert axis (leading E of wg/wu/wd) over "model".
  * Decode caches: batch over DP when divisible, else sequence; heads over
    "model" when divisible, else sequence/feature.
Param leaves stacked by scan get a leading None (depth axis is never
sharded).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.lm.config import LMConfig, ShapeCell

M = "model"


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# (regex, spec WITHOUT the stacked-depth axis). First match wins.
_PARAM_RULES = [
    # embeddings / head
    (r"^embed$", P(M, None)),
    (r"^lm_head$", P(None, M)),
    (r"^final_norm$", P(None)),
    # attention
    (r"attn/w[qkv]$", P(None, M)),
    (r"attn/wo$", P(M, None)),
    (r"attn/b[qkv]$", P(M)),
    (r"attn/tau$", P()),
    # dense mlp
    (r"mlp/(wg|wu|wi)$", P(None, M)),
    (r"mlp/wd$", P(M, None)),
    # moe (expert parallel on leading E)
    (r"moe/router$", P(None, None)),
    (r"moe/(wg|wu|wd)$", P(M, None, None)),
    # mamba2
    (r"(^|/)m/(w_z|w_x)$", P(None, M)),
    (r"(^|/)m/(w_B|w_C|w_dt)$", P(None, M)),
    (r"(^|/)m/conv_w$", P(None, M)),
    (r"(^|/)m/conv_b$", P(M)),
    (r"(^|/)m/(A_log|D|dt_bias)$", P(M)),
    (r"(^|/)m/norm_w$", P(M)),
    (r"(^|/)m/out_proj$", P(M, None)),
    # mlstm
    (r"b/(w_gate|w_up)$", P(None, M)),
    (r"b/w[qkv]$", P(None, M)),
    (r"b/wif$", P(None, None)),
    (r"b/norm_w$", P(M)),
    (r"b/down$", P(M, None)),
    # slstm
    (r"b/w_in$", P(None, M)),
    (r"b/r$", P(None, None, M)),
    (r"b/b$", P(M)),
    # layer norms
    (r"ln\d?$|/ln$", P(None)),
]


def _match_spec(path: str, shape, n_stack: int) -> P:
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            full = P(*([None] * n_stack + list(spec)))
            # verify divisibility of every sharded dim; fall back to replicate
            return full
    return P(*([None] * len(shape)))


def _stack_depth(path: str, cfg: LMConfig) -> int:
    """How many leading stacked-scan axes this leaf carries."""
    if path.startswith("blocks/"):
        if cfg.block_pattern == "zamba2" and "/mamba/" in path:
            return 2      # (groups, mamba_per_attn, ...)
        if cfg.block_pattern == "xlstm" and "/mlstm/" in path:
            return 2
        return 1
    return 0


def _check_divisible(spec: P, shape, mesh: Mesh) -> P:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ok = []
    for dim, s in zip(shape, spec):
        if s is None:
            ok.append(None)
            continue
        names = s if isinstance(s, tuple) else (s,)
        size = int(np.prod([axes[n] for n in names]))
        ok.append(s if dim % size == 0 else None)
    return P(*ok)


def param_specs(abstract_params, cfg: LMConfig, mesh: Mesh,
                policy: str = "tp"):
    """PartitionSpec tree matching an (abstract) param tree.

    policy:
      tp   - Megatron tensor parallel over "model" (baseline rules above)
      fsdp - fully-sharded data parallel: every matched weight shards its
             first non-depth dim over ALL mesh axes; weights are gathered
             per layer (bf16) instead of activations being all-reduced —
             wins when B_local*S*d >> layer params (the train_4k regime).
      zero3 - like fsdp but weights shard over the "model" axis only and
             batch stays on the data axes: per-layer bf16 weight gathers
             replace TP activation all-reduces while keeping the baseline
             activation layout (B_local=16) so GSPMD propagation is tame.
      cp   - context parallelism: weights FSDP-stored over the data axes
             (output dim; gathered per layer since the batch owns "data"),
             sequence sharded over "model" between blocks (use
             act_sharding=dp_sp) — MLPs become collective-free, attention
             pays one K/V all-gather over "model".
    """

    all_axes = tuple(mesh.axis_names)

    def leaf(path, x):
        p = _path_str(path)
        # serve-quantized leaves are (w_q, w_scale) tuples: match the base
        # path; scales get the matched spec's LAST-dim entry only.
        is_scale = False
        if re.search(r"/(0|1)$", p):
            is_scale = p.endswith("/1")
            p = p[:-2]
        n_stack = _stack_depth(p, cfg)
        if is_scale:
            base = _match_spec(p, x.shape, n_stack)
            spec = [None] * len(x.shape)
            if len(base) >= 1 and len(x.shape) >= 1:
                spec[-1] = base[len(base) - 1] if len(base) == len(x.shape) \
                    else (base[-1] if base else None)
            return _check_divisible(P(*spec), x.shape, mesh)
        if policy in ("fsdp", "zero3", "cp"):
            matched = any(re.search(pat, p) for pat, _ in _PARAM_RULES)
            spec = [None] * len(x.shape)
            if policy == "cp":
                dp_axes = tuple(a for a in all_axes if a != M)
                dp_axes = dp_axes[0] if len(dp_axes) == 1 else dp_axes
                if matched and len(x.shape) > n_stack:
                    spec[-1] = dp_axes      # FSDP storage on the output dim
            else:
                shard_axes = all_axes if policy == "fsdp" else M
                if matched and len(x.shape) > n_stack:
                    spec[n_stack] = shard_axes
            spec = P(*spec)
        else:
            spec = _match_spec(p, x.shape, n_stack)
            if len(spec) < len(x.shape):  # pad missing minor axes
                spec = P(*(list(spec) + [None] * (len(x.shape) - len(spec))))
        return _check_divisible(spec, x.shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf, abstract_params)


def batch_specs(cfg: LMConfig, cell: ShapeCell, mesh: Mesh,
                policy: str = "tp") -> Dict[str, P]:
    if policy == "fsdp":
        axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        total = int(np.prod(list(axes.values())))
        dp = tuple(mesh.axis_names) if cell.global_batch % total == 0 \
            else tuple(a for a in mesh.axis_names if a != M)
    else:
        dp = tuple(a for a in mesh.axis_names if a != M)
    dp = dp[0] if len(dp) == 1 else dp
    if cell.kind == "decode" and cell.global_batch == 1:
        dp_b = None                 # batch=1: replicate batch
    else:
        dp_b = dp
    if cfg.frontend == "token":
        specs = {"tokens": P(dp_b, None)}
    else:
        specs = {"embeds": P(dp_b, None, None)}
    if cell.kind == "train":
        specs["labels"] = P(dp_b, None)
    return specs


def cache_specs(abstract_cache, cfg: LMConfig, cell: ShapeCell, mesh: Mesh,
                mlstm_state_shard: bool = False):
    """Decode-cache specs: batch over DP if divisible else None; for KV
    caches, heads over model if divisible else the sequence axis.

    mlstm_state_shard: shard the mLSTM matrix state's d_k dim over "model".
    Measured on the dry-run this forces SPMD involuntary full
    rematerialization (collective-permutes of the state every step) because
    the per-step read contracts over the sharded dim; default False
    (replicate over model, batch-shard only) cuts decode collectives ~400x
    (see EXPERIMENTS.md §Perf cell 2)."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in mesh.axis_names if a != M)
    dp_size = int(np.prod([axes[a] for a in dp]))
    dp = dp[0] if len(dp) == 1 else dp
    model_size = axes[M]

    def leaf(path, x):
        p = _path_str(path)
        shape = x.shape
        # leading axes: stacked scan groups (skip), then batch
        n_stack = _stack_depth(p + "", cfg) if p.startswith("blocks") else 0
        spec = [None] * len(shape)
        bdim = n_stack
        if shape[bdim] % dp_size == 0 and cell.global_batch > 1:
            spec[bdim] = dp
            batch_sharded = True
        else:
            batch_sharded = False
        if re.search(r"/(k|v|k_q|v_q|k_s|v_s)$", p):
            # (..., B, kv_heads, S, hd) or scales (..., B, kv_heads, S)
            hdim, sdim = bdim + 1, bdim + 2
            if shape[hdim] % model_size == 0:
                spec[hdim] = M
            elif shape[sdim] % model_size == 0:
                spec[sdim] = M
            if not batch_sharded and shape[sdim] % dp_size == 0 \
                    and spec[sdim] is None:
                spec[sdim] = dp     # long_500k: shard sequence over DP
        elif re.search(r"/ssm$", p):
            if shape[bdim + 1] % model_size == 0:
                spec[bdim + 1] = M   # heads
        elif re.search(r"/conv$", p):
            if shape[bdim + 2] % model_size == 0:
                spec[bdim + 2] = M   # d_inner
        elif re.search(r"/state$", p):   # mlstm (B, H, dk, dv)
            # shard the VALUE dim over model: aligned with column-parallel
            # wv / row-parallel down, so per-step read/write stay local
            if shape[bdim + 3] % model_size == 0:
                spec[bdim + 3] = M
            elif mlstm_state_shard and shape[bdim + 2] % model_size == 0:
                spec[bdim + 2] = M
        elif re.search(r"/norm$", p):    # mlstm normalizer (B, H, dk)
            pass  # batch-sharded only (tiny)
        elif re.search(r"/(h|c|n|m)$", p):  # slstm (B, d)
            if shape[bdim + 1] % model_size == 0:
                spec[bdim + 1] = M
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, abstract_cache)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
