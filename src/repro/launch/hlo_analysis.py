"""Optimized-HLO analysis: collective bytes with while-loop trip expansion.

XLA's executable-level cost_analysis counts each while-loop body ONCE, which
silently undercounts anything inside a lax.scan (our layer stacks and
attention/SSD chunk loops). This walker parses the compiled HLO text into
computations, extracts per-computation collective bytes, reads each loop's
trip count from its condition computation (the s32 bound constant), and
multiplies recursively. The result is the true per-step collective traffic
of the deployed program.

Charging convention: each collective op is charged its RESULT tensor bytes
(all-reduce: operand size; all-gather: gathered size; reduce-scatter:
scattered size; all-to-all / collective-permute: transferred size).
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(r"=\s*(.+?)\s(" + "|".join(COLLECTIVES) +
                      r")(?:-start|-done)?\(")
_WHILE_RE = re.compile(r"\swhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        b = _DTYPE_BYTES.get(m.group(1))
        if b is None:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * b
    return int(total)


def split_computations(hlo: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            m = _HDR_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
        if cur is not None:
            comps[cur].append(line)
    comps["__entry__"] = entry
    return comps


def analyze_collectives(hlo: str) -> Tuple[Dict[str, int], Dict[str, int]]:
    """Returns (bytes per collective kind, op-executions per kind), with
    while-loop bodies multiplied by their trip counts."""
    comps = split_computations(hlo)
    entry = comps.pop("__entry__")

    def comp_trip(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        bounds = [int(m.group(1)) for l in lines for m in _CONST_RE.finditer(l)]
        return max(bounds) if bounds else 1

    local: Dict[str, Tuple[Dict[str, int], Dict[str, int], list]] = {}
    for name, lines in comps.items():
        by = {k: 0 for k in COLLECTIVES}
        ct = {k: 0 for k in COLLECTIVES}
        whiles = []
        for line in lines:
            m = _COLL_RE.search(line)
            if m and "-done(" not in line:   # count start (or plain), not done
                kind = m.group(2)
                by[kind] += _shape_bytes(m.group(1))
                ct[kind] += 1
            w = _WHILE_RE.search(line)
            if w:
                whiles.append((w.group(1), w.group(2)))
        local[name] = (by, ct, whiles)

    memo: Dict[str, Tuple[Dict[str, int], Dict[str, int]]] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        if name not in local or depth > 16:
            return ({k: 0 for k in COLLECTIVES}, {k: 0 for k in COLLECTIVES})
        by, ct, whiles = local[name]
        by, ct = dict(by), dict(ct)
        for cond, body in whiles:
            trips = comp_trip(cond)
            b2, c2 = total(body, depth + 1)
            for k in COLLECTIVES:
                by[k] += trips * b2[k]
                ct[k] += trips * c2[k]
        memo[name] = (by, ct)
        return memo[name]

    return total(entry)


def loop_summary(hlo: str) -> list:
    """(cond, body, trips) for every while in the entry — debugging aid."""
    comps = split_computations(hlo)
    comps.pop("__entry__")
    out = []
    for name, lines in comps.items():
        for line in lines:
            w = _WHILE_RE.search(line)
            if w:
                cond = w.group(1)
                bounds = [int(m.group(1)) for l in comps.get(cond, [])
                          for m in _CONST_RE.finditer(l)]
                out.append((name, w.group(2), max(bounds) if bounds else 1))
    return out
