"""Distributed step functions (train / prefill / decode) + input specs.

These are the functions the launcher jits with explicit in/out shardings and
the dry-run lowers for every (arch x shape x mesh) cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import transformer as tfm
from repro.models.lm.config import LMConfig, ShapeCell
from repro.optim.adamw import AdamW, AdamWState


def make_train_step(cfg: LMConfig, opt: AdamW, grad_specs=None):
    """(params, opt_state, batch) -> (params, opt_state, loss).

    grad_specs: optional PartitionSpec tree; constraining gradients to the
    parameter shardings right after autodiff forces GSPMD to lower the
    data-axis gradient reduction as reduce-scatter instead of all-reduce
    (perf iteration; ZeRO-2-style)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(tfm.lm_loss)(params, cfg, batch)
        if grad_specs is not None:
            grads = jax.tree.map(
                lambda g, sp: jax.lax.with_sharding_constraint(g, sp),
                grads, grad_specs)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, loss

    return train_step


def make_prefill_step(cfg: LMConfig):
    """(params, batch) -> logits. Inference prefill (no cache write-back —
    the cost-dominant forward pass; cache construction adds only stores)."""

    def prefill_step(params, batch):
        logits, _ = tfm.forward(params, cfg, tokens=batch.get("tokens"),
                                embeds=batch.get("embeds"))
        return logits

    return prefill_step


def make_serve_step(cfg: LMConfig):
    """(params, cache, tokens, cur_index) -> (logits, cache). One new token
    against a seq_len KV/state cache."""

    def serve_step(params, cache, tokens, cur_index):
        return tfm.decode_step(params, cfg, cache, tokens, cur_index)

    return serve_step


def input_specs(cfg: LMConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = cell.global_batch, cell.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cell.kind == "train":
        if cfg.frontend == "token":
            return {"tokens": tok, "labels": tok}
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype),
                "labels": tok}
    if cell.kind == "prefill":
        if cfg.frontend == "token":
            return {"tokens": tok}
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)}
    # decode: one new token + full cache of length S
    if cfg.frontend == "token":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    return {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), cfg.dtype)}


def abstract_params(cfg: LMConfig):
    def build():
        base = (dataclasses.replace(cfg, quant_mode="none")
                if cfg.quant_mode.startswith("serve") else cfg)
        p = tfm.init_lm(jax.random.PRNGKey(0), base)
        if cfg.quant_mode.startswith("serve"):
            from repro.quant.apply import quantize_params_tree
            p = quantize_params_tree(p, cfg)
        return p

    return jax.eval_shape(build)


def abstract_cache(cfg: LMConfig, cell: ShapeCell):
    return jax.eval_shape(
        lambda: tfm.init_cache(cfg, cell.global_batch, cell.seq_len))


def abstract_opt_state(cfg: LMConfig, opt: AdamW):
    params = abstract_params(cfg)
    return jax.eval_shape(opt.init, params)
