"""Quantized serving launcher — both repo workloads behind one CLI.

LM decode (the memory-wall demo, unchanged semantics):

  PYTHONPATH=src python -m repro.launch.serve --workload lm --arch qwen2-0.5b \
      --smoke --quant serve_w8a8 --kv-quant --tokens 32 --batch 4

SO(3) force-field inference through `repro.serving.QuantizedEngine`
(batched + bucketed + Pallas-kernel quantized — the paper's headline path):

  PYTHONPATH=src python -m repro.launch.serve --workload so3 --mode w8a8 \
      --graphs 32 --min-atoms 6 --max-atoms 48

The so3 workload builds an engine, warms up its shape classes, pushes a
stream of variable-size molecules through `infer_batch`, and reports
molecules/s, the weight-memory compression, and the served model's LEE
diagnostic (padding masked out).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM decode workload (KV-cached token loop)
# ---------------------------------------------------------------------------

def run_lm(args) -> None:
    from repro import configs
    from repro.models.lm import transformer as tfm
    from repro.quant.apply import quantize_params_tree, quantized_bytes

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, quant_mode=args.quant,
                              kv_quant=args.kv_quant,
                              dtype=jnp.float32 if args.smoke else cfg.dtype)

    params = tfm.init_lm(jax.random.PRNGKey(0),
                         dataclasses.replace(cfg, quant_mode="none"))
    fp32_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    if args.quant != "none":
        params = quantize_params_tree(params, cfg)
    served_bytes = quantized_bytes(params)
    cache = tfm.init_cache(cfg, args.batch, args.cache_len)
    cache_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(cache))

    @jax.jit
    def step(params, cache, tok, idx):
        logits, cache = tfm.decode_step(params, cfg, cache, tok, idx)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return nxt, cache

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    if cfg.frontend != "token":
        tok = jnp.zeros((args.batch, 1, cfg.d_model), cfg.dtype)
    # warm
    nxt, cache = step(params, cache, tok, jnp.asarray(0, jnp.int32))
    jax.block_until_ready(nxt)
    t0 = time.time()
    for i in range(1, args.tokens):
        nxt, cache = step(params, cache,
                          nxt if cfg.frontend == "token" else tok,
                          jnp.asarray(i, jnp.int32))
    jax.block_until_ready(nxt)
    dt = time.time() - t0
    tps = (args.tokens - 1) * args.batch / dt
    print(f"arch={cfg.name} quant={args.quant} kv_quant={args.kv_quant}")
    print(f"weights: fp32 {fp32_bytes/1e6:.2f} MB -> served "
          f"{served_bytes/1e6:.2f} MB ({fp32_bytes/max(served_bytes,1):.2f}x)")
    print(f"kv-cache: {cache_bytes/1e6:.2f} MB for B={args.batch} "
          f"S={args.cache_len}")
    print(f"decode: {tps:.1f} tok/s ({dt/(args.tokens-1)*1e3:.1f} ms/step)")


# ---------------------------------------------------------------------------
# SO(3) force-field workload (QuantizedEngine)
# ---------------------------------------------------------------------------

def run_so3(args) -> None:
    from repro.models import so3krates as so3
    from repro.serving import QuantizedEngine, ServeConfig, random_graphs

    model_cfg = so3.So3kratesConfig(feat=args.feat, vec_feat=args.vec_feat,
                                    n_layers=args.layers, n_rbf=8,
                                    dir_bits=args.dir_bits)
    serve = ServeConfig(mode=args.mode,
                        bucket_sizes=tuple(args.buckets),
                        max_batch=args.max_batch,
                        path=args.path)
    engine = QuantizedEngine.from_config(model_cfg, serve=serve)
    graphs = random_graphs(args.graphs, args.min_atoms, args.max_atoms,
                           model_cfg.n_species, density=args.density)

    mem = engine.memory_report()
    print(f"workload=so3 mode={args.mode} backend={engine.backend} "
          f"interpret={engine.interpret}")
    print(f"weights: fp32 {mem['fp32_bytes']/1e3:.1f} KB -> served "
          f"{mem['served_bytes']/1e3:.1f} KB ({mem['compression_x']}x)")

    # warm the exact shape classes this traffic will use, so the timed
    # pass below measures steady-state throughput, not compilation
    t0 = time.time()
    engine.infer_batch(graphs)
    print(f"warmup: compiled {len(engine.compiled_shapes)} shape "
          f"class(es) in {time.time() - t0:.2f}s")

    t0 = time.time()
    results = engine.infer_batch(graphs)
    dt = time.time() - t0
    buckets_used = sorted({r.bucket_capacity for r in results})
    paths_used = sorted({r.path for r in results})
    print(f"infer_batch: {len(graphs)} molecules "
          f"({args.min_atoms}-{args.max_atoms} atoms) in {dt:.2f}s "
          f"-> {len(graphs)/dt:.1f} mol/s, buckets used {buckets_used}, "
          f"paths {paths_used} (dispatch {engine.dispatch_stats})")

    if args.lee:
        diag = engine.lee_diagnostic(graphs[:4], jax.random.PRNGKey(1),
                                     n_rotations=2)
        print(f"served-model LEE: mean {diag['lee_mean']:.2e} "
              f"max {diag['lee_max']:.2e} (padding masked)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default="lm", choices=["lm", "so3"])
    # lm options
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none",
                    choices=["none", "serve_w8a8", "serve_w4a8"])
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    # so3 options
    ap.add_argument("--mode", default="w8a8",
                    choices=["fp32", "w8a8", "w4a8"])
    ap.add_argument("--graphs", type=int, default=16)
    ap.add_argument("--min-atoms", type=int, default=6)
    ap.add_argument("--max-atoms", type=int, default=32)
    ap.add_argument("--buckets", type=int, nargs="+", default=[16, 32, 64])
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--feat", type=int, default=32)
    ap.add_argument("--vec-feat", type=int, default=8)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--dir-bits", type=int, default=8)
    ap.add_argument("--path", default="auto",
                    choices=["dense", "sparse", "auto"],
                    help="so3 execution path: dense O(n^2), or the "
                         "sparse O(E) edge list (sparse/auto; batches "
                         "whose cutoff graph overflows the bucket's edge "
                         "capacity fall back to dense, see dispatch "
                         "stats)")
    ap.add_argument("--density", type=float, default=None,
                    help="atoms per cubic Angstrom for the random graphs "
                         "(None = legacy dense cloud)")
    ap.add_argument("--lee", action="store_true",
                    help="also report the served model's LEE diagnostic")
    args = ap.parse_args()

    if args.workload == "lm":
        if not args.arch:
            ap.error("--workload lm requires --arch")
        run_lm(args)
    else:
        run_so3(args)


if __name__ == "__main__":
    main()
