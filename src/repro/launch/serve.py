"""Quantized serving launcher: batched decode with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --quant serve_w8a8 --kv-quant --tokens 32 --batch 4

Demonstrates the paper's memory-wall fix end-to-end: weights stored int8
(or int4-packed), KV cache int8, decode loop jit'd once and stepped with a
static-shape cache. Reports tokens/s and the weight+cache byte footprint vs
fp32 (the bandwidth-multiplier the roofline predicts).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.lm import transformer as tfm
from repro.quant.apply import quantize_params_tree, quantized_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none",
                    choices=["none", "serve_w8a8", "serve_w4a8"])
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, quant_mode=args.quant,
                              kv_quant=args.kv_quant,
                              dtype=jnp.float32 if args.smoke else cfg.dtype)

    params = tfm.init_lm(jax.random.PRNGKey(0),
                         dataclasses.replace(cfg, quant_mode="none"))
    fp32_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    if args.quant != "none":
        params = quantize_params_tree(params, cfg)
    served_bytes = quantized_bytes(params)
    cache = tfm.init_cache(cfg, args.batch, args.cache_len)
    cache_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(cache))

    @jax.jit
    def step(params, cache, tok, idx):
        logits, cache = tfm.decode_step(params, cfg, cache, tok, idx)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return nxt, cache

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    if cfg.frontend != "token":
        tok = jnp.zeros((args.batch, 1, cfg.d_model), cfg.dtype)
    # warm
    nxt, cache = step(params, cache, tok, jnp.asarray(0, jnp.int32))
    jax.block_until_ready(nxt)
    t0 = time.time()
    out_tokens = []
    for i in range(1, args.tokens):
        nxt, cache = step(params, cache,
                          nxt if cfg.frontend == "token" else tok,
                          jnp.asarray(i, jnp.int32))
        out_tokens.append(np.asarray(nxt)[:, 0])
    jax.block_until_ready(nxt)
    dt = time.time() - t0
    tps = (args.tokens - 1) * args.batch / dt
    print(f"arch={cfg.name} quant={args.quant} kv_quant={args.kv_quant}")
    print(f"weights: fp32 {fp32_bytes/1e6:.2f} MB -> served "
          f"{served_bytes/1e6:.2f} MB ({fp32_bytes/max(served_bytes,1):.2f}x)")
    print(f"kv-cache: {cache_bytes/1e6:.2f} MB for B={args.batch} "
          f"S={args.cache_len}")
    print(f"decode: {tps:.1f} tok/s ({dt/(args.tokens-1)*1e3:.1f} ms/step)")


if __name__ == "__main__":
    main()
