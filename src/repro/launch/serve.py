"""Quantized serving launcher — both repo workloads behind one CLI.

LM decode (the memory-wall demo, unchanged semantics):

  PYTHONPATH=src python -m repro.launch.serve --workload lm --arch qwen2-0.5b \
      --smoke --quant serve_w8a8 --kv-quant --tokens 32 --batch 4

SO(3) force-field inference through `repro.serving.QuantizedEngine`
(batched + bucketed + Pallas-kernel quantized — the paper's headline path):

  PYTHONPATH=src python -m repro.launch.serve --workload so3 --mode w8a8 \
      --graphs 32 --min-atoms 6 --max-atoms 48

The so3 workload builds an engine, warms up its shape classes, pushes a
stream of variable-size molecules through `infer_batch`, and reports
molecules/s, the weight-memory compression, and the served model's LEE
diagnostic (padding masked out).

Online serving demo (`repro.server`, docs/server.md) — Poisson traffic
through the dynamic micro-batching scheduler, latency percentiles and
dispatch stats instead of one-shot batch timing:

  PYTHONPATH=src python -m repro.launch.serve --workload so3 --server \
      --rate 20 --requests 200 --deadline-ms 25 \
      [--artifact model.npz]        # cold-start from a packed artifact

`--save-artifact path.npz` packs the engine's quantized weights to disk;
`--artifact path.npz` boots from one (skipping fp32 + quantization).

Multi-replica cluster demo (`repro.cluster`, docs/cluster.md) — the
same traffic fanned across N device-pinned engine replicas behind the
shape-aware router, with an optional zero-downtime rolling weight swap
mid-replay:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.serve --workload so3 --server \
      --replicas 4 --rate 60 --requests 300 [--swap-artifact v2.npz]

`--md-session N` additionally streams a checkpointed N-step MD session
through the same pool beside the one-shot traffic (`repro.sessions`,
docs/sessions.md).

Runtime guardrails (`repro.guardrails`, docs/guardrails.md):
`--guardrails` arms the engine-side detectors (non-finite results are
withheld with a typed error instead of delivered); `--tiers
w4a8:2,w8a8:1,fp32:1` serves through a mixed-precision fleet whose
flagged requests transparently re-run one tier up; `--stall-timeout S`
arms the pool watchdog that quarantines and cold-restarts a replica
whose worker stalls:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.serve --workload so3 --server \
      --tiers w4a8:2,w8a8:1,fp32:1 --guardrails --stall-timeout 5
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LM decode workload (KV-cached token loop)
# ---------------------------------------------------------------------------

def run_lm(args) -> None:
    from repro import configs
    from repro.models.lm import transformer as tfm
    from repro.quant.apply import quantize_params_tree, quantized_bytes

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, quant_mode=args.quant,
                              kv_quant=args.kv_quant,
                              dtype=jnp.float32 if args.smoke else cfg.dtype)

    params = tfm.init_lm(jax.random.PRNGKey(0),
                         dataclasses.replace(cfg, quant_mode="none"))
    fp32_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    if args.quant != "none":
        params = quantize_params_tree(params, cfg)
    served_bytes = quantized_bytes(params)
    cache = tfm.init_cache(cfg, args.batch, args.cache_len)
    cache_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(cache))

    @jax.jit
    def step(params, cache, tok, idx):
        logits, cache = tfm.decode_step(params, cfg, cache, tok, idx)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return nxt, cache

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    if cfg.frontend != "token":
        tok = jnp.zeros((args.batch, 1, cfg.d_model), cfg.dtype)
    # warm
    nxt, cache = step(params, cache, tok, jnp.asarray(0, jnp.int32))
    jax.block_until_ready(nxt)
    t0 = time.monotonic()
    for i in range(1, args.tokens):
        nxt, cache = step(params, cache,
                          nxt if cfg.frontend == "token" else tok,
                          jnp.asarray(i, jnp.int32))
    jax.block_until_ready(nxt)
    dt = time.monotonic() - t0
    tps = (args.tokens - 1) * args.batch / dt
    print(f"arch={cfg.name} quant={args.quant} kv_quant={args.kv_quant}")
    print(f"weights: fp32 {fp32_bytes/1e6:.2f} MB -> served "
          f"{served_bytes/1e6:.2f} MB ({fp32_bytes/max(served_bytes,1):.2f}x)")
    print(f"kv-cache: {cache_bytes/1e6:.2f} MB for B={args.batch} "
          f"S={args.cache_len}")
    print(f"decode: {tps:.1f} tok/s ({dt/(args.tokens-1)*1e3:.1f} ms/step)")


# ---------------------------------------------------------------------------
# SO(3) force-field workload (QuantizedEngine)
# ---------------------------------------------------------------------------

def _artifact_mode(path: str) -> str:
    """The serving mode a packed artifact was quantized for."""
    from repro.server import load_artifact
    return load_artifact(path).serve.mode


def run_so3(args) -> None:
    from repro.models import so3krates as so3
    from repro.serving import QuantizedEngine, ServeConfig, random_graphs
    from repro.server import load_engine, save_artifact

    if args.artifact:
        # packed-artifact cold start: no fp32 tree, no quantization
        # pass. The mode is baked into the packed weights, so it comes
        # from the artifact unless the user explicitly asks (and an
        # explicit mismatch is an error, not a silent override).
        t0 = time.monotonic()
        mode = args.mode or _artifact_mode(args.artifact)
        serve = ServeConfig(mode=mode, bucket_sizes=tuple(args.buckets),
                            max_batch=args.max_batch, path=args.path)
        engine = load_engine(args.artifact, serve=serve)
        model_cfg = engine.model_cfg
        print(f"cold start from {args.artifact} in "
              f"{time.monotonic() - t0:.2f}s "
              "(packed weights, no quantization pass)")
    else:
        serve = ServeConfig(mode=args.mode or "w8a8",
                            bucket_sizes=tuple(args.buckets),
                            max_batch=args.max_batch,
                            path=args.path)
        model_cfg = so3.So3kratesConfig(feat=args.feat,
                                        vec_feat=args.vec_feat,
                                        n_layers=args.layers, n_rbf=8,
                                        dir_bits=args.dir_bits)
        engine = QuantizedEngine.from_config(model_cfg, serve=serve)
    if args.guardrails:
        from repro.guardrails import GuardrailConfig
        engine.guardrails = GuardrailConfig(check_finite=True)
        print("guardrails: non-finite results are withheld with a typed "
              "GuardrailViolation (docs/guardrails.md)")
    if args.save_artifact:
        nbytes = save_artifact(args.save_artifact, engine)
        print(f"packed artifact -> {args.save_artifact} "
              f"({nbytes / 1e3:.1f} KB)")

    mem = engine.memory_report()
    print(f"workload=so3 mode={engine.serve.mode} backend={engine.backend} "
          f"interpret={engine.interpret}")
    print(f"weights: fp32 {mem['fp32_bytes']/1e3:.1f} KB -> served "
          f"{mem['served_bytes']/1e3:.1f} KB ({mem['compression_x']}x)")

    if args.server:
        run_so3_server(engine, args)
        return

    graphs = random_graphs(args.graphs, args.min_atoms, args.max_atoms,
                           model_cfg.n_species, density=args.density)

    # warm the exact shape classes this traffic will use, so the timed
    # pass below measures steady-state throughput, not compilation
    t0 = time.monotonic()
    engine.infer_batch(graphs)
    print(f"warmup: compiled {len(engine.compiled_shapes)} shape "
          f"class(es) in {time.monotonic() - t0:.2f}s")

    t0 = time.monotonic()
    results = engine.infer_batch(graphs)
    dt = time.monotonic() - t0
    buckets_used = sorted({r.bucket_capacity for r in results})
    paths_used = sorted({r.path for r in results})
    print(f"infer_batch: {len(graphs)} molecules "
          f"({args.min_atoms}-{args.max_atoms} atoms) in {dt:.2f}s "
          f"-> {len(graphs)/dt:.1f} mol/s, buckets used {buckets_used}, "
          f"paths {paths_used} (dispatch {engine.dispatch_stats})")

    if args.lee:
        diag = engine.lee_diagnostic(graphs[:4], jax.random.PRNGKey(1),
                                     n_rotations=2)
        print(f"served-model LEE: mean {diag['lee_mean']:.2e} "
              f"max {diag['lee_max']:.2e} (padding masked)")


def run_so3_server(engine, args) -> None:
    """Online-serving demo: Poisson traffic through the dynamic
    micro-batching scheduler (`repro.server`) — or, with `--replicas`,
    through the multi-replica cluster pool (`repro.cluster`, one engine
    per JAX device) — latency percentiles and dispatch stats. With
    `--swap-artifact` a zero-downtime rolling weight swap fires halfway
    through the replay (docs/cluster.md)."""
    import threading

    from repro.server import (MicroBatchScheduler, SchedulerConfig,
                              SizeClass, TrafficConfig, make_traffic,
                              run_open_loop)

    mid = (args.min_atoms + args.max_atoms) // 2
    if mid + 1 > args.max_atoms:      # degenerate range: one size class
        size_mix = (SizeClass(args.min_atoms, args.max_atoms, 1.0),)
    else:
        size_mix = (SizeClass(args.min_atoms, mid, 0.5),
                    SizeClass(mid + 1, args.max_atoms, 0.5))
    cfg = TrafficConfig(
        rate_rps=args.rate, n_requests=args.requests,
        size_mix=size_mix,
        n_species=engine.model_cfg.n_species, density=args.density,
        seed=args.seed)
    traffic = make_traffic(cfg)
    max_batch = min(args.sched_batch, args.max_batch)

    if (args.replicas > 1 or args.swap_artifact or args.md_session
            or args.tiers):
        from repro.cluster import ClusterConfig, ClusterPool
        cluster = ClusterConfig(n_replicas=args.replicas,
                                max_batch=max_batch,
                                deadline_ms=args.deadline_ms,
                                max_queue=args.max_queue,
                                stall_timeout_s=args.stall_timeout)
        if args.tiers:
            # mixed-precision fleet: flagged w4a8 results re-run one
            # tier up (fresh random weights shared across the tiers —
            # a demo fleet, like the non-artifact engine above)
            plan = {}
            for part in args.tiers.split(","):
                t, _, k = part.partition(":")
                plan[t.strip()] = int(k or 1)
            pool = ClusterPool.from_tiers(
                engine.model_cfg, serve=engine.serve, tier_plan=plan,
                cluster=cluster, seed=args.seed,
                guardrails=engine.guardrails if args.guardrails else None)
        else:
            pool = ClusterPool.from_quantized(
                engine.model_cfg, engine.qparams, engine.serve, cluster,
                fp32_nbytes=engine.memory_report()["fp32_bytes"],
                artifact_version=engine.artifact_version,
                guardrails=engine.guardrails if args.guardrails else None)
        alert_bus = getattr(args, "_alert_bus", None)
        if alert_bus is not None:
            # fleet surfacing: alerts land in pool.stats()["alerts"] and
            # bump pool_events_total{event="alert"}
            pool.watch_alerts(alert_bus)
        swap_report = {}
        swap_thread = None
        session = session_mgr = None
        with pool:
            s0 = pool.stats()
            print(f"cluster: {pool.n_replicas} replicas on "
                  f"{[r['device'] for r in s0['replicas']]}, parallel "
                  f"warmup {s0['warmup_s']:.2f}s")
            pool.reset_stats()
            if args.md_session:
                session, session_mgr = _start_md_session(pool, engine,
                                                         args)
            if args.swap_artifact:
                # fire the rolling swap halfway through the replay; a
                # failure must surface after the replay, not vanish into
                # the timer thread's excepthook
                half = traffic[len(traffic) // 2][0]

                def do_swap():
                    try:
                        swap_report.update(
                            pool.swap_artifact(args.swap_artifact))
                    except BaseException as e:
                        swap_report["error"] = e
                swap_thread = threading.Timer(half, do_swap)
                swap_thread.start()
            res = run_open_loop(pool, traffic, rate_rps=args.rate)
            if swap_thread is not None:
                # a rolling swap warms each replacement engine before the
                # exchange, which can outlast a short replay — wait so the
                # report is real and the pool isn't torn down under a
                # thread that is mid-compilation
                if not swap_report:
                    print("replay done; waiting for the rolling swap to "
                          "finish...")
                swap_thread.join()
            if session is not None:
                session.wait()
                session_mgr.close()
            stats = pool.stats()
        _print_server_summary(res, stats, args, max_batch)
        if session is not None:
            print(f"md session: {session.steps_done} steps in "
                  f"{len(session.collected)} frames beside the replay, "
                  f"{session.n_checkpoints} checkpoints "
                  f"({session.checkpoint_dir}), "
                  f"artifact versions "
                  f"{sorted({f.artifact_version for f in session.collected})}")
        print(f"routing: {stats['router']['routed_per_replica']} "
              f"(shed {stats['n_shed']}, requeued "
              f"{stats['router']['n_requeued']})")
        if args.tiers or args.guardrails or args.stall_timeout:
            g = stats.get("guardrails", {})
            print(f"tiers: {stats.get('tiers')}  guardrails: flagged "
                  f"{g.get('n_flagged', 0)}, escalated "
                  f"{g.get('n_escalated', 0)}, quarantined "
                  f"{g.get('n_quarantined', 0)}, stalls detected "
                  f"{g.get('n_stalls_detected', 0)}")
        if swap_report.get("error") is not None:
            raise SystemExit(
                f"hot swap FAILED: {swap_report['error']} (traffic was "
                "unaffected — surviving weights kept serving)")
        if swap_report:
            pauses = [f"{r['pause_s'] * 1e3:.2f}ms"
                      for r in swap_report["replicas"]]
            print(f"hot swap -> {swap_report['version_tag']}: "
                  f"per-replica serve pauses {pauses} "
                  "(warmed before swap; zero requests dropped)")
        return

    sched_cfg = SchedulerConfig(max_batch=max_batch,
                                deadline_ms=args.deadline_ms,
                                max_queue=args.max_queue)
    with MicroBatchScheduler(engine, sched_cfg) as sched:
        print(f"warmup: {sched.warmup_s:.2f}s "
              f"({len(engine.compiled_shapes)} shape classes)")
        engine.reset_stats()    # keep the streaming phase unpolluted
        res = run_open_loop(sched, traffic, rate_rps=args.rate)
        stats = sched.stats()
    _print_server_summary(res, stats, args, max_batch)


def _start_md_session(pool, engine, args):
    """`--md-session N`: stream a checkpointed MD trajectory through the
    pool while the one-shot replay runs (repro.sessions,
    docs/sessions.md). Returns (session, manager); the caller waits and
    closes after the replay so both tenants share the replicas."""
    import tempfile

    import numpy as np

    from repro.md.engine import MDConfig
    from repro.sessions import SessionConfig, SessionManager

    n = max(args.min_atoms, (args.min_atoms + args.max_atoms) // 2)
    rng = np.random.default_rng(args.seed + 1)
    side = (n / (args.density or 0.1)) ** (1.0 / 3.0)
    species = rng.integers(0, engine.model_cfg.n_species,
                           n).astype(np.int32)
    coords = rng.uniform(0, side, size=(n, 3)).astype(np.float32)
    masses = np.full(n, 12.0, np.float32)
    record = min(50, args.md_session)
    chunk = 2 * record if 2 * record <= args.md_session else record
    scfg = SessionConfig(
        n_steps=args.md_session, chunk_steps=chunk, record_every=record,
        checkpoint_every=3,
        md=MDConfig(mode=engine.serve.mode, record_every=record))
    root = tempfile.mkdtemp(prefix="serve_md_session_")
    mgr = SessionManager(pool, root)
    session = mgr.start(species, coords, masses, config=scfg,
                        seed=args.seed)
    print(f"md session: {args.md_session} NVE steps ({n} atoms, "
          f"{scfg.n_chunks} chunks of {chunk}) streaming beside the "
          f"replay; checkpoints -> {session.checkpoint_dir}")
    return session, mgr


def _print_server_summary(res, stats, args, max_batch) -> None:
    s = res.summary()
    print(f"open loop: {args.requests} requests at {args.rate:.1f} req/s "
          f"offered ({args.min_atoms}-{args.max_atoms} atoms, "
          f"deadline {args.deadline_ms:.0f} ms, "
          f"micro-batch <= {max_batch})")
    print(f"latency: p50 {s['p50_ms']:.1f} ms  p95 {s['p95_ms']:.1f} ms  "
          f"p99 {s['p99_ms']:.1f} ms  max {s['max_ms']:.1f} ms")
    print(f"throughput: {s['throughput_rps']:.1f} req/s over "
          f"{s['span_s']:.1f}s span")
    print(f"batching: {stats['n_flushes']} flushes, mean batch "
          f"{stats['mean_batch']:.2f}, reasons {stats['flush_reasons']}, "
          f"max queue depth {stats['max_queue_depth']}")
    print(f"dispatch: {stats['engine_dispatch']}")


def _setup_obs(args):
    """`--metrics-out` / `--trace-out` / `--alerts-out`: arm the unified
    metrics plane, the per-request tracer, and the active health plane
    (SLO burn-rate evaluation + anomaly detectors; repro.obs,
    docs/observability.md).  Returns a cleanup callable that stops the
    health monitor, flushes the final export, and closes the sinks."""
    if not (args.metrics_out or args.trace_out or args.alerts_out):
        return lambda: None
    from repro.obs import (AlertBus, AnomalyMonitor, HealthMonitor,
                           JsonlTraceSink, PeriodicExporter, REGISTRY,
                           SLOEvaluator, TRACER, configure_tracing,
                           default_detectors, default_slos)
    sink = exporter = monitor = alerts_file = None
    if args.trace_out:
        sink = JsonlTraceSink(args.trace_out)
        configure_tracing(enabled=True, sink=sink)
        print(f"tracing: per-request spans -> {args.trace_out} "
              "(render with scripts/trace_report.py)")
    if args.metrics_out:
        exporter = PeriodicExporter(
            args.metrics_out, interval_s=args.export_interval,
            tracer=TRACER if sink is not None else None,
            trace_sink=None).start()
        print(f"metrics: Prometheus text exposition -> "
              f"{args.metrics_out} every {args.export_interval:.0f}s")
    if args.alerts_out:
        REGISTRY.set_enabled(True)     # the evaluators read the registry
        bus = AlertBus(registry=REGISTRY)
        alerts_file = open(args.alerts_out, "a", encoding="utf-8")

        def on_alert(alert):
            alerts_file.write(json.dumps(alert.to_json()) + "\n")
            alerts_file.flush()
            print(f"ALERT[{alert.severity}] {alert.name}: "
                  f"{alert.message}")
        bus.subscribe(on_alert)
        evaluator = SLOEvaluator(default_slos(), registry=REGISTRY,
                                 bus=bus)
        anomaly = AnomalyMonitor(default_detectors(), registry=REGISTRY,
                                 bus=bus)
        monitor = HealthMonitor([evaluator, anomaly],
                                interval_s=args.health_interval).start()
        args._alert_bus = bus      # cluster path: pool.watch_alerts
        print(f"health plane: {len(evaluator.slos)} SLOs + "
              f"{len(anomaly.detectors)} anomaly detectors every "
              f"{args.health_interval:.1f}s, alerts -> {args.alerts_out}")

    def cleanup():
        if monitor is not None:
            monitor.stop()         # one final evaluation step
        if exporter is not None:
            exporter.stop()        # joins + writes one final export
        if alerts_file is not None:
            alerts_file.close()
        if sink is not None:
            configure_tracing(enabled=False)
            sink.close()
            print(f"tracing: {sink.n_written} trace(s) written to "
                  f"{args.trace_out}")
    return cleanup


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", default="lm", choices=["lm", "so3"])
    # lm options
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none",
                    choices=["none", "serve_w8a8", "serve_w4a8"])
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    # so3 options
    ap.add_argument("--mode", default=None,
                    choices=["fp32", "w8a8", "w4a8"],
                    help="serving mode (default: w8a8, or the artifact's "
                         "own mode when --artifact is given)")
    ap.add_argument("--graphs", type=int, default=16)
    ap.add_argument("--min-atoms", type=int, default=6)
    ap.add_argument("--max-atoms", type=int, default=32)
    ap.add_argument("--buckets", type=int, nargs="+", default=[16, 32, 64])
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--feat", type=int, default=32)
    ap.add_argument("--vec-feat", type=int, default=8)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--dir-bits", type=int, default=8)
    ap.add_argument("--path", default="auto",
                    choices=["dense", "sparse", "auto"],
                    help="so3 execution path: dense O(n^2), or the "
                         "sparse O(E) edge list (sparse/auto; batches "
                         "whose cutoff graph overflows the bucket's edge "
                         "capacity fall back to dense, see dispatch "
                         "stats)")
    ap.add_argument("--density", type=float, default=None,
                    help="atoms per cubic Angstrom for the random graphs "
                         "(None = legacy dense cloud)")
    ap.add_argument("--lee", action="store_true",
                    help="also report the served model's LEE diagnostic")
    # so3 online-serving mode (repro.server, docs/server.md)
    ap.add_argument("--server", action="store_true",
                    help="stream Poisson traffic through the dynamic "
                         "micro-batching scheduler and report latency "
                         "percentiles + dispatch stats")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="offered load in requests/s (--server)")
    ap.add_argument("--requests", type=int, default=200,
                    help="number of requests to stream (--server)")
    ap.add_argument("--deadline-ms", type=float, default=25.0,
                    help="micro-batching deadline (--server)")
    ap.add_argument("--sched-batch", type=int, default=8,
                    help="scheduler micro-batch flush size (--server)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a repro.cluster pool of this many "
                         "engine replicas, one per JAX device (--server; "
                         "on CPU simulate devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission: shed requests beyond this "
                         "many queued per scheduler/replica (--server)")
    ap.add_argument("--swap-artifact",
                    help="rolling zero-downtime weight swap to this "
                         "packed artifact halfway through the --server "
                         "replay (implies the cluster path)")
    ap.add_argument("--md-session", type=int, default=0, metavar="STEPS",
                    help="also stream a checkpointed MD session of this "
                         "many NVE steps through the pool beside the "
                         "one-shot traffic (repro.sessions, "
                         "docs/sessions.md; --server, implies the "
                         "cluster path)")
    ap.add_argument("--guardrails", action="store_true",
                    help="arm the runtime result detectors: non-finite "
                         "energies/forces are withheld with a typed "
                         "error instead of delivered "
                         "(repro.guardrails, docs/guardrails.md)")
    ap.add_argument("--tiers", metavar="SPEC",
                    help="serve through a mixed-precision fleet, e.g. "
                         "'w4a8:2,w8a8:1,fp32:1' — flagged requests "
                         "transparently re-run one precision tier up "
                         "(--server, implies the cluster path)")
    ap.add_argument("--stall-timeout", type=float, default=None,
                    metavar="S",
                    help="arm the pool watchdog: a replica whose worker "
                         "is stuck on one flush/chunk longer than this "
                         "is quarantined and cold-restarted, its "
                         "requests requeued (--server cluster path)")
    ap.add_argument("--metrics-out", metavar="PATH",
                    help="export the unified metrics registry as "
                         "Prometheus text exposition to this file, "
                         "rewritten atomically every --export-interval "
                         "seconds (repro.obs, docs/observability.md)")
    ap.add_argument("--trace-out", metavar="PATH",
                    help="enable per-request tracing and append one "
                         "JSON trace per completed request to this "
                         "file; render the latency breakdown with "
                         "scripts/trace_report.py")
    ap.add_argument("--export-interval", type=float, default=5.0,
                    metavar="S",
                    help="metrics export period in seconds "
                         "(--metrics-out)")
    ap.add_argument("--alerts-out", metavar="PATH",
                    help="arm the active health plane: evaluate the "
                         "default SLO catalogue (burn-rate windows) and "
                         "anomaly detectors against the live registry "
                         "and append one JSON alert per line to this "
                         "file (repro.obs.slo, docs/observability.md); "
                         "watch live with scripts/obs_top.py")
    ap.add_argument("--health-interval", type=float, default=1.0,
                    metavar="S",
                    help="health-plane evaluation period in seconds "
                         "(--alerts-out)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--artifact",
                    help="cold-start the engine from a packed quantized "
                         "artifact (.npz) instead of quantizing fp32")
    ap.add_argument("--save-artifact",
                    help="pack the engine's quantized weights to this "
                         ".npz and continue")
    args = ap.parse_args()

    cleanup_obs = _setup_obs(args)
    try:
        if args.workload == "lm":
            if not args.arch:
                ap.error("--workload lm requires --arch")
            run_lm(args)
        else:
            run_so3(args)
    finally:
        cleanup_obs()


if __name__ == "__main__":
    main()
