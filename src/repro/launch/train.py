"""Distributed training launcher.

Production posture on a small footprint: pjit'd train step with explicit
shardings, synthetic sharded token pipeline, fault-tolerant checkpointing
with auto-resume, error-feedback gradient compression, straggler watchdog.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 50 --batch 8 --seq 256 --smoke   # CPU-sized smoke run

`--smoke` swaps in the reduced config of the same family and a 1x1 mesh so
the whole loop (including checkpoint/restore) runs in this container; without
it the full config is used (real-cluster path; identical code).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.tokens import synthetic_token_batches
from repro.launch import sharding as shd
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.lm import transformer as tfm
from repro.models.lm.config import ShapeCell
from repro.optim.adamw import AdamW, cosine_schedule
from repro.optim.compression import ef_compress, ef_init


class StragglerWatchdog:
    """Aborts a hung SPMD step so the launcher can restart from the last
    checkpoint — the single-process analogue of a collective timeout."""

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s

    def __enter__(self):
        if self.timeout_s > 0:
            signal.signal(signal.SIGALRM, self._fire)
            signal.setitimer(signal.ITIMER_REAL, self.timeout_s)
        return self

    def _fire(self, *_):
        raise TimeoutError(f"step exceeded {self.timeout_s}s (straggler?)")

    def __exit__(self, *exc):
        if self.timeout_s > 0:
            signal.setitimer(signal.ITIMER_REAL, 0)
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="none",
                    choices=["none", "qat_w4a8"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "ef8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--spmd-timeout", type=float, default=0.0)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, quant_mode=args.quant,
                              dtype=jnp.float32 if args.smoke else cfg.dtype,
                              attn_chunk_q=min(1024, args.seq),
                              ssm_chunk=min(cfg.ssm_chunk, args.seq))
    mesh = (make_local_mesh() if args.smoke
            else make_production_mesh(multi_pod=args.multi_pod))
    cell = ShapeCell("custom", args.seq, args.batch, "train")

    params = tfm.init_lm(jax.random.PRNGKey(0), cfg)
    p_specs = shd.param_specs(params, cfg, mesh)
    p_sh = shd.to_shardings(p_specs, mesh)
    params = jax.tree.map(jax.device_put, params, p_sh)

    opt = AdamW(lr=cosine_schedule(args.lr, args.steps // 10, args.steps),
                weight_decay=0.1, grad_clip=1.0)
    opt_state = opt.init(params)
    ef_state = ef_init(params) if args.grad_compression == "ef8" else None

    ckpt_dir = args.ckpt_dir or os.path.join("artifacts", "ckpt",
                                             cfg.name.replace("/", "_"))
    mgr = CheckpointManager(ckpt_dir, keep=2)
    start_step = 0
    latest = mgr.latest_step()
    if latest is not None:
        print(f"[resume] restoring step {latest} from {ckpt_dir}")
        params = mgr.restore(latest, params, p_sh)
        start_step = latest + 1

    use_ef = args.grad_compression == "ef8"

    def train_step(params, opt_state, ef_state, batch):
        loss, grads = jax.value_and_grad(tfm.lm_loss)(params, cfg, batch)
        if use_ef:
            grads, ef_state = ef_compress(grads, ef_state)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, ef_state, loss

    b_specs = shd.batch_specs(cfg, cell, mesh)
    b_sh = {k: NamedSharding(mesh, v) for k, v in b_specs.items()}
    step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2))

    data_iter = synthetic_token_batches(cfg, args.batch, args.seq, seed=17)
    losses = []
    t_start = time.monotonic()
    with mesh:
        for step in range(start_step, args.steps):
            batch = next(data_iter)
            batch = {k: jax.device_put(v, b_sh.get(k, b_sh.get("tokens")))
                     for k, v in batch.items()}
            with StragglerWatchdog(args.spmd_timeout):
                params, opt_state, ef_state, loss = step_fn(
                    params, opt_state, ef_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                loss_f = float(loss)
                losses.append(loss_f)
                print(f"step {step:5d} loss {loss_f:.4f} "
                      f"({(time.monotonic()-t_start):.1f}s)", flush=True)
            if args.ckpt_every and step and step % args.ckpt_every == 0:
                mgr.save(step, params, extra={"loss": float(loss)})

    mgr.save(args.steps - 1, params, extra={"loss": float(loss)})
    print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
