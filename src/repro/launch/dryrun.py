import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices build the production meshes; jax.jit(...).lower(...).compile()
must succeed, memory_analysis() proves per-device fit, cost_analysis() +
collective parsing feed the roofline (EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh single [--quant serve_w8a8] [--kv-quant]
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Writes artifacts/dryrun/<arch>__<shape>__<mesh>[__<tag>].json
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (abstract_cache, abstract_opt_state,
                                abstract_params, input_specs, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.launch import costs as costs_lib
from repro.launch.hlo_analysis import analyze_collectives
from repro.models.lm.config import SHAPES
from repro.optim.adamw import AdamW

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts",
                   "dryrun")

def _spec_trees(cfg, cell, mesh, policy="tp"):
    params = abstract_params(cfg)
    p_specs = shd.param_specs(params, cfg, mesh, policy)
    b_specs = shd.batch_specs(cfg, cell, mesh, policy)
    return params, p_specs, b_specs


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             quant_mode: str = "none", kv_quant: bool = False,
             kv_bits: int = 8, kv_replicate: int = 1,
             attn_chunk_q: int = 1024, remat: bool = False,
             act_sharding: str = "none", policy: str = "tp",
             norm_f32: bool = True, grad_rs: bool = False,
             mlstm_state_shard: bool = False, tag: str = "") -> dict:
    cell = next(s for s in SHAPES if s.shape_name == shape_name)
    cfg = configs.get_config(arch, quant_mode=quant_mode, kv_quant=kv_quant,
                             kv_bits=kv_bits, kv_replicate=kv_replicate,
                             attn_chunk_q=attn_chunk_q, remat=remat,
                             act_sharding=act_sharding, norm_f32=norm_f32)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.monotonic()

    params, p_specs, b_specs = _spec_trees(cfg, cell, mesh, policy)
    p_sh = shd.to_shardings(p_specs, mesh)
    batch = input_specs(cfg, cell)
    b_sh = {k: NamedSharding(mesh, b_specs[k]) for k in batch}

    if cell.kind == "train":
        opt = AdamW(lr=1e-4, weight_decay=0.1)
        opt_state = abstract_opt_state(cfg, opt)
        # AdamW mu/nu mirror the parameter shardings; step counter replicated
        from repro.optim.adamw import AdamWState
        opt_sh = AdamWState(step=NamedSharding(mesh, P()), mu=p_sh, nu=p_sh)
        step = make_train_step(cfg, opt,
                               grad_specs=p_specs if grad_rs else None)
        jitted = jax.jit(step,
                         in_shardings=(p_sh, opt_sh, b_sh),
                         out_shardings=(p_sh, opt_sh, NamedSharding(mesh, P())),
                         donate_argnums=(0, 1))
        args = (params, opt_state, batch)
    elif cell.kind == "prefill":
        step = make_prefill_step(cfg)
        logits_sh = NamedSharding(mesh, P(None, None, "model"))
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=logits_sh)
        args = (params, batch)
    else:  # decode
        cache = abstract_cache(cfg, cell)
        c_specs = shd.cache_specs(cache, cfg, cell, mesh,
                                  mlstm_state_shard=mlstm_state_shard)
        c_sh = shd.to_shardings(c_specs, mesh)
        step = make_serve_step(cfg)
        logits_sh = NamedSharding(mesh, P(None, "model"))
        jitted = jax.jit(step,
                         in_shardings=(p_sh, c_sh, b_sh["tokens"]
                                       if "tokens" in b_sh else b_sh["embeds"],
                                       NamedSharding(mesh, P())),
                         out_shardings=(logits_sh, c_sh),
                         donate_argnums=(1,))
        tok = batch.get("tokens", batch.get("embeds"))
        args = (params, cache, tok, jax.ShapeDtypeStruct((), jnp.int32))

    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll_bytes, coll_counts = analyze_collectives(compiled.as_text())
    an_flops = costs_lib.cell_flops(cfg, cell)
    an_bytes = costs_lib.cell_hbm_bytes(cfg, cell)
    mflops = costs_lib.model_flops(cfg, cell)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "quant_mode": quant_mode, "kv_quant": kv_quant, "tag": tag,
        "act_sharding": act_sharding, "policy": policy,
        "kind": cell.kind, "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        "n_devices": int(len(mesh.devices.ravel())),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", -1),
        },
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "analytic_flops": an_flops,
        "analytic_hbm_bytes": an_bytes,
        "model_flops": mflops,
        "param_count": configs.get_config(arch).param_count(),
        "active_param_count": configs.get_config(arch).active_param_count(),
    }
    return rec


def cell_path(arch, shape, mesh_kind, tag=""):
    name = f"{arch}__{shape}__{mesh_kind}" + (f"__{tag}" if tag else "")
    return os.path.join(ART, name + ".json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="none")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=8)
    ap.add_argument("--kv-replicate", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--attn-chunk-q", type=int, default=1024)
    ap.add_argument("--act-sharding", default="none",
                    choices=["none", "dp", "dp_sp"])
    ap.add_argument("--policy", default="tp", choices=["tp", "fsdp", "zero3", "cp"])
    ap.add_argument("--norm-bf16", action="store_true")
    ap.add_argument("--grad-rs", action="store_true")
    ap.add_argument("--mlstm-state-shard", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(ART, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s.shape_name) for a in configs.ARCH_IDS
                 for s in configs.shapes_for(a)]
    else:
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            path = cell_path(arch, shape, mk, args.tag)
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {path}")
                continue
            print(f"[dryrun] {arch} x {shape} x {mk} "
                  f"quant={args.quant} kv={args.kv_quant}", flush=True)
            try:
                rec = run_cell(arch, shape, mk, quant_mode=args.quant,
                               kv_quant=args.kv_quant, kv_bits=args.kv_bits,
                               kv_replicate=args.kv_replicate,
                               remat=args.remat,
                               attn_chunk_q=args.attn_chunk_q,
                               act_sharding=args.act_sharding,
                               policy=args.policy,
                               norm_f32=not args.norm_bf16,
                               grad_rs=args.grad_rs,
                               mlstm_state_shard=args.mlstm_state_shard,
                               tag=args.tag)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                print(f"  ok: flops={rec['flops']:.3e} "
                      f"bytes={rec['bytes_accessed']:.3e} "
                      f"coll={sum(rec['collective_bytes'].values()):.3e} "
                      f"compile={rec['compile_s']}s", flush=True)
            except Exception as e:
                failures += 1
                print(f"  FAIL: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
