"""Analytic per-cell cost model: FLOPs and HBM bytes for every block type.

Why analytic: XLA's executable cost_analysis counts while-loop bodies once,
so anything under lax.scan (layer stacks, attention/SSD chunk loops) is
undercounted by its trip count. Rather than unroll (intractable compile
times at 80 layers x 32k tokens), we compute implementation-faithful costs
from the architecture algebra. Collective traffic IS taken from the compiled
HLO (hlo_analysis.py) since it depends on GSPMD decisions we don't model.

Conventions
  * FLOPs: 2*MAC for matmuls/einsums; elementwise ignored (<1%).
  * Attention counts the deployed implementation's work: q-chunked blockwise
    attention evaluates ALL (q, kv) pairs with causal masking -> 2x the
    causally-useful work for train/prefill. The MODEL_FLOPS ratio in the
    roofline surfaces exactly this kind of overhead.
  * HBM bytes: weights + caches + the activation tensors that round-trip HBM
    (block inputs/outputs, written fwd / read bwd); attention logits and SSD
    chunk temporaries are VMEM-resident by construction (that is the point
    of the chunked formulations).
  * All numbers are GLOBAL (whole cluster, one step); divide by n_chips for
    per-chip roofline terms.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.lm.config import LMConfig, ShapeCell
from repro.models.lm.moe import MOE_GROUP


def _dtype_bytes(cfg: LMConfig) -> float:
    import jax.numpy as jnp
    return 2.0 if cfg.dtype == jnp.bfloat16 else 4.0


def _weight_bytes_per_param(cfg: LMConfig) -> float:
    if cfg.quant_mode == "serve_w8a8":
        return 1.0
    if cfg.quant_mode == "serve_w4a8":
        return 0.5
    import jax.numpy as jnp
    return 4.0 if cfg.param_dtype == jnp.float32 else 2.0


# --------------------------------------------------------------------------
# per-layer forward FLOPs (per token unless noted)
# --------------------------------------------------------------------------

def _attn_proj_flops(cfg) -> float:
    return 2 * cfg.d_model * cfg.hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
        + 2 * cfg.n_heads * cfg.hd * cfg.d_model


def _attn_score_flops(cfg, s_ctx: float) -> float:
    """Per token, attending over s_ctx keys (QK^T + PV)."""
    return 2 * 2 * cfg.n_heads * cfg.hd * s_ctx


def _mlp_flops(cfg) -> float:
    if cfg.mlp_kind == "swiglu":
        return 2 * 3 * cfg.d_model * cfg.d_ff
    if cfg.mlp_kind == "squared_relu":
        return 2 * 2 * cfg.d_model * cfg.d_ff
    return 0.0


def _moe_flops(cfg, tokens_per_group: float) -> float:
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    C = max(tokens_per_group * k / E * cf, 1.0)
    router = 2 * cfg.d_model * E
    # dispatch+combine einsums: 2 ops x 2MAC x E*C*d per group of Tg tokens
    per_tok_dispatch = 2 * 2 * E * C * cfg.d_model / tokens_per_group
    experts = 2 * 3 * k * cf * cfg.d_model * cfg.d_ff
    return router + per_tok_dispatch + experts


def _mamba_flops(cfg, decode: bool) -> float:
    d, di = cfg.d_model, cfg.d_inner
    H, N, G, P = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_head_dim
    L = cfg.ssm_chunk
    proj = 2 * d * (2 * di + 2 * G * N + H) + 2 * di * d
    conv = 2 * 4 * di
    if decode:
        scan = 2 * G * N + 2 * H * P + 4 * H * N * P
    else:
        scan = 2 * G * L * N + 2 * H * L * (P + 1) + 4 * H * N * P
    return proj + conv + scan


def _mlstm_flops(cfg, decode: bool) -> float:
    d = cfg.d_model
    di = d * cfg.xlstm_proj_factor
    H = cfg.n_heads
    dk, dv = di // H // 2, di // H
    L = cfg.ssm_chunk
    proj = 2 * d * 2 * di + 2 * di * (2 * H * dk + H * dv + 2 * H) + 2 * di * d
    if decode:
        scan = 4 * H * dk * (dv + 1)
    else:
        scan = 2 * H * L * dk + 2 * H * L * (dv + 1) + 4 * H * dk * (dv + 1)
    return proj + scan


def _slstm_flops(cfg) -> float:
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    return 2 * d * 4 * d + 2 * H * dh * 4 * dh + 2 * d * d


def forward_flops_per_token(cfg: LMConfig, cell: ShapeCell) -> float:
    """Implementation FLOPs per token, forward pass, whole network."""
    decode = cell.kind == "decode"
    S = cell.seq_len
    # context length each token attends over in the deployed implementation
    if decode:
        s_ctx = S                       # one token vs full cache
    else:
        s_ctx = S                       # blockwise attention: ALL pairs
    T_group = min(MOE_GROUP, cell.global_batch * (1 if decode else S))

    if cfg.block_pattern == "transformer":
        per_layer = _attn_proj_flops(cfg) + _attn_score_flops(cfg, s_ctx)
        per_layer += _moe_flops(cfg, T_group) if cfg.moe else _mlp_flops(cfg)
        body = cfg.n_layers * per_layer
    elif cfg.block_pattern == "zamba2":
        G = cfg.n_layers // cfg.zamba_mamba_per_attn
        body = cfg.n_layers * _mamba_flops(cfg, decode)
        body += G * (_attn_proj_flops(cfg) + _attn_score_flops(cfg, s_ctx)
                     + _mlp_flops(cfg))
    elif cfg.block_pattern == "xlstm":
        Gg = cfg.n_layers // (cfg.xlstm_mlstm_per_slstm + 1)
        n_m = cfg.n_layers - Gg
        body = n_m * _mlstm_flops(cfg, decode) + Gg * _slstm_flops(cfg)
    else:
        raise ValueError(cfg.block_pattern)
    head = 2 * cfg.d_model * cfg.vocab
    return body + head


def cell_flops(cfg: LMConfig, cell: ShapeCell) -> float:
    """Total implementation FLOPs for one step (global)."""
    tokens = cell.global_batch * (1 if cell.kind == "decode" else cell.seq_len)
    fwd = tokens * forward_flops_per_token(cfg, cell)
    return 3.0 * fwd if cell.kind == "train" else fwd


def model_flops(cfg: LMConfig, cell: ShapeCell) -> float:
    """The 6*N*D (train) / 2*N*D (inference) yardstick, N = active params."""
    tokens = cell.global_batch * (1 if cell.kind == "decode" else cell.seq_len)
    N = cfg.active_param_count()
    return (6.0 if cell.kind == "train" else 2.0) * N * tokens


# --------------------------------------------------------------------------
# HBM bytes
# --------------------------------------------------------------------------

def _activation_width(cfg: LMConfig) -> float:
    """Block-level activation tensors that round-trip HBM, per token, in
    units of elements (see module docstring)."""
    d = cfg.d_model
    if cfg.block_pattern == "transformer":
        per = 4 * d + (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd
        per += 2 * cfg.d_ff if cfg.mlp_kind == "swiglu" else cfg.d_ff
        if cfg.moe:
            per += 2 * cfg.top_k * cfg.capacity_factor * cfg.d_ff
        return cfg.n_layers * per
    if cfg.block_pattern == "zamba2":
        di = cfg.d_inner
        per_mamba = 3 * d + 3 * di
        G = cfg.n_layers // cfg.zamba_mamba_per_attn
        per_attn = 4 * d + (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd + 2 * cfg.d_ff
        return cfg.n_layers * per_mamba + G * per_attn
    if cfg.block_pattern == "xlstm":
        di = d * cfg.xlstm_proj_factor
        Gg = cfg.n_layers // (cfg.xlstm_mlstm_per_slstm + 1)
        return (cfg.n_layers - Gg) * (3 * d + 4 * di) + Gg * (2 * d + 4 * d)
    raise ValueError(cfg.block_pattern)


def _cache_bytes(cfg: LMConfig, cell: ShapeCell) -> float:
    """Total decode-cache bytes (global)."""
    B, S = cell.global_batch, cell.seq_len
    kv_b = (cfg.kv_bits / 8.0 + 4.0 / cfg.hd) if cfg.kv_quant else _dtype_bytes(cfg)
    if cfg.block_pattern == "transformer":
        return (cfg.n_layers * B * cfg.n_kv_heads * cfg.kv_replicate * S
                * cfg.hd * 2 * kv_b)
    if cfg.block_pattern == "zamba2":
        G = cfg.n_layers // cfg.zamba_mamba_per_attn
        attn = G * B * cfg.n_kv_heads * S * cfg.hd * 2 * kv_b
        ssm = cfg.n_layers * B * (cfg.n_ssm_heads * cfg.ssm_state
                                  * cfg.ssm_head_dim * 4 + 3 * cfg.d_inner * 2)
        return attn + ssm
    if cfg.block_pattern == "xlstm":
        di = cfg.d_model * cfg.xlstm_proj_factor
        H = cfg.n_heads
        dk, dv = di // H // 2, di // H
        Gg = cfg.n_layers // (cfg.xlstm_mlstm_per_slstm + 1)
        mlstm = (cfg.n_layers - Gg) * B * H * dk * (dv + 1) * 4
        slstm = Gg * B * 4 * cfg.d_model * 4
        return mlstm + slstm
    raise ValueError(cfg.block_pattern)


def cell_hbm_bytes(cfg: LMConfig, cell: ShapeCell) -> Dict[str, float]:
    """Global HBM traffic for one step, split by source."""
    tokens = cell.global_batch * (1 if cell.kind == "decode" else cell.seq_len)
    P = cfg.param_count()
    wb = _weight_bytes_per_param(cfg)
    act_b = _dtype_bytes(cfg)
    act_elems = _activation_width(cfg) * tokens

    if cell.kind == "train":
        # fwd read + bwd read of weights; grads write+read; adam: read p,mu,nu
        # + write p,mu,nu (fp32 master)
        weights = P * (2 * wb + 2 * 4 + 6 * 4)
        acts = act_elems * act_b * 2            # write fwd, read bwd
        cache = 0.0
        logits = cell.global_batch * cell.seq_len * cfg.vocab * 4 * 2
    elif cell.kind == "prefill":
        weights = P * wb
        acts = act_elems * act_b
        cache = 0.0
        logits = cell.global_batch * cell.seq_len * cfg.vocab * 4
    else:  # decode
        weights = P * wb
        acts = act_elems * act_b
        cache = _cache_bytes(cfg, cell)          # read full cache once
        logits = cell.global_batch * cfg.vocab * 4
    return {"weights": weights, "activations": acts, "cache": cache,
            "logits": logits, "total": weights + acts + cache + logits}
