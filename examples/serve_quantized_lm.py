"""Example: quantized serving for both workloads via repro.launch.serve.

1. LM decode (memory-wall fix): fp32 vs W8A8 vs W4A8 (+ int8 KV cache),
   memory footprint and tokens/s on the qwen2-0.5b family smoke config.
2. SO(3) force-field inference: the same quantized-kernel path behind
   `repro.serving.QuantizedEngine` — batched, bucketed, variable-size
   molecules (see examples/md_stability.py for the trained-model variant).

Run:  PYTHONPATH=src python examples/serve_quantized_lm.py
"""
import os
import subprocess
import sys

env = dict(os.environ, PYTHONPATH="src")

for quant, kv in [("none", False), ("serve_w8a8", True), ("serve_w4a8", True)]:
    cmd = [sys.executable, "-m", "repro.launch.serve", "--workload", "lm",
           "--arch", "qwen2-0.5b", "--smoke", "--quant", quant,
           "--tokens", "16", "--batch", "2", "--cache-len", "64"] \
        + (["--kv-quant"] if kv else [])
    print(f"\n== lm quant={quant} kv_quant={kv} ==")
    subprocess.run(cmd, check=True, env=env)

print("\n== so3 batched quantized engine (w8a8) ==")
subprocess.run([sys.executable, "-m", "repro.launch.serve",
                "--workload", "so3", "--mode", "w8a8", "--graphs", "8",
                "--min-atoms", "6", "--max-atoms", "24",
                "--buckets", "16", "32", "--lee"],
               check=True, env=env)
