"""Example: quantized LM serving (the memory-wall fix applied to decode).

Loads the qwen2-0.5b *family* smoke config, compares fp32 vs W8A8 vs W4A8
(+ int8 KV cache) decode: memory footprint and tokens/s on CPU.

Run:  PYTHONPATH=src python examples/serve_quantized_lm.py
"""
import subprocess
import sys
import os

env = dict(os.environ, PYTHONPATH="src")
for quant, kv in [("none", False), ("serve_w8a8", True), ("serve_w4a8", True)]:
    cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2-0.5b",
           "--smoke", "--quant", quant, "--tokens", "16", "--batch", "2",
           "--cache-len", "64"] + (["--kv-quant"] if kv else [])
    print(f"\n== quant={quant} kv_quant={kv} ==")
    subprocess.run(cmd, check=True, env=env)
