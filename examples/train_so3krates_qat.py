"""Example: the paper's full workflow at laptop scale.

FP32-train a So3krates force field on the synthetic azobenzene dataset, then
QAT-finetune it with GAQ (W4A8 + MDDQ + geometric STE + LEE regularization),
and compare against naive INT8. ~5 minutes on CPU.

Run:  PYTHONPATH=src python examples/train_so3krates_qat.py
"""
import jax

from repro.data.synthetic_md import sample_dataset
from repro.models import so3krates as so3
from repro.training.pipeline import lee_eval
from repro.training.so3_trainer import TrainConfig, evaluate, train

BASE = dict(feat=32, vec_feat=8, n_layers=2)

data = sample_dataset(jax.random.PRNGKey(0), 128)
mev = float(data["e_scale"]) * 1000

print("== FP32 training ==")
cfg32 = so3.So3kratesConfig(**BASE, quant="none")
params32, _ = train(cfg32, data, TrainConfig(epochs=30, warmup_epochs=0,
                                             batch_size=32, lr=5e-3),
                    verbose=True)
ev = evaluate(cfg32, params32, data)
print(f"fp32: E-MAE {ev['e_mae']*mev:.1f} meV, F-MAE {ev['f_mae']*mev:.1f} meV/A")

for name, kw in [("GAQ W4A8", dict(quant="gaq_w4a8", dir_bits=12)),
                 ("naive INT8", dict(quant="naive_int8",
                                     robust_attention=False))]:
    print(f"== QAT finetune: {name} ==")
    cfg = so3.So3kratesConfig(**BASE, **kw)
    params, _ = train(cfg, data,
                      TrainConfig(epochs=8, warmup_epochs=2, batch_size=32,
                                  lr=1e-3, lee_weight=1.0),
                      init=params32, verbose=True)
    ev = evaluate(cfg, params, data)
    lee = lee_eval(cfg, params, data, n_rot=4, n_cfg=4)
    print(f"{name}: E-MAE {ev['e_mae']*mev:.1f} meV, "
          f"F-MAE {ev['f_mae']*mev:.1f} meV/A, LEE {lee*mev:.2f} meV/A")
