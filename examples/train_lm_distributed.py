"""Example: end-to-end distributed-style LM training driver (~100M-class
smoke model, few hundred steps) with checkpoint/auto-resume and QAT.

Run:  PYTHONPATH=src python examples/train_lm_distributed.py
"""
import os
import subprocess
import sys

env = dict(os.environ, PYTHONPATH="src")
subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--arch", "llama3.2-3b", "--smoke", "--steps", "200",
                "--batch", "8", "--seq", "128", "--ckpt-every", "100",
                "--quant", "qat_w4a8", "--grad-compression", "ef8"],
               check=True, env=env)
