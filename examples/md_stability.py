"""Example: NVE molecular dynamics with a learned (and quantized) force
field — the paper's Fig. 3 experiment at reduced scale — plus the
deployment check: the same trained weights served through the batched
quantized engine (`repro.serving.QuantizedEngine`).

Uses the pipeline's trained checkpoints if present (artifacts/so3/), else
trains a quick FP32 model. Runs NVE, reports the energy drift rate, then
builds a W8A8 engine from the trained params and reports how closely the
served (kernel-quantized, batched) forces track the fp32 model on test
frames, together with the served model's LEE diagnostic.

Run:  PYTHONPATH=src python examples/md_stability.py [--steps 4000]
"""
import argparse
import os

import jax
import numpy as np

from repro.data.synthetic_md import sample_dataset
from repro.models import so3krates as so3
from repro.serving import Graph, QuantizedEngine, ServeConfig
from repro.training import pipeline as pipe
from repro.training.so3_trainer import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=4000)
ap.add_argument("--serve-mode", default="w8a8",
                choices=["fp32", "w8a8", "w4a8"])
args = ap.parse_args()

data = sample_dataset(jax.random.PRNGKey(0), 128)

ckpt = os.path.join(pipe.ART, "ckpt_fp32.npz")
if os.path.exists(ckpt):
    cfg = so3.So3kratesConfig(**pipe.BASE, **pipe.METHODS["fp32"])
    params = pipe.load_params(ckpt)
    print("using pipeline checkpoint", ckpt)
else:
    cfg = so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2)
    params, _ = train(cfg, data, TrainConfig(epochs=30, warmup_epochs=0,
                                             batch_size=32, lr=5e-3))

res = pipe.nve_eval(cfg, params, data, n_steps=args.steps, dt_fs=0.25)
print(f"NVE {args.steps} steps @0.25fs: drift "
      f"{res['drift_ev_per_atom_ps']*1000:.3f} meV/atom/ps, "
      f"blew_up={res['blew_up']}, wall {res['wall_s']:.1f}s")

# --- deployment check: serve the trained model through the batched engine ---
engine = QuantizedEngine.from_config(
    cfg, params=params,
    serve=ServeConfig(mode=args.serve_mode, bucket_sizes=(32,),
                      max_batch=8))
mem = engine.memory_report()
print(f"\nserving mode={args.serve_mode} backend={engine.backend} "
      f"interpret={engine.interpret}: fp32 {mem['fp32_bytes']/1e3:.1f} KB -> "
      f"{mem['served_bytes']/1e3:.1f} KB ({mem['compression_x']}x)")

frames = [Graph(species=np.asarray(data["species"]),
                coords=np.asarray(data["coords"][i]))
          for i in range(8)]
served = engine.infer_batch(frames)
f_ref = np.stack([np.asarray(so3.forces(params, cfg, data["species"],
                                        data["coords"][i]))
                  for i in range(8)])
f_srv = np.stack([r.forces for r in served])
fmae = float(np.abs(f_srv - f_ref).mean())
print(f"served vs fp32 forces on 8 test frames: MAE {fmae:.4f} "
      f"(scaled units)")
diag = engine.lee_diagnostic(frames[:4], jax.random.PRNGKey(3),
                             n_rotations=2)
print(f"served-model LEE: mean {diag['lee_mean']:.3e} "
      f"max {diag['lee_max']:.3e}")
