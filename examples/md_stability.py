"""Example: NVE molecular dynamics with a learned (and quantized) force
field — the paper's Fig. 3 experiment at reduced scale — run through the
device-resident MD engine (`repro.md.MDEngine`): quantized sparse
forward inside `lax.scan`, Verlet-skin neighbour lists rebuilt on
device, host contact only at record checkpoints.

Uses the pipeline's trained checkpoints if present (artifacts/so3/),
else trains a quick FP32 model. Builds a serving engine from the trained
weights, bridges it into an MDEngine (`engine.md_engine()` — MD and
serving share one set of quantized parameters), runs NVE, and reports
the energy drift rate, the skin-rebuild frequency, and how closely the
served (kernel-quantized, batched) forces track the fp32 model,
together with the served model's LEE diagnostic.

Run:  PYTHONPATH=src python examples/md_stability.py [--steps 4000]
"""
import argparse
import os

import jax
import numpy as np

from repro.data.synthetic_md import sample_dataset
from repro.md import MDConfig, energy_drift_rate, pad_replicas
from repro.models import so3krates as so3
from repro.serving import Graph, QuantizedEngine, ServeConfig
from repro.training import pipeline as pipe
from repro.training.so3_trainer import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=4000)
ap.add_argument("--dt-fs", type=float, default=0.25)
ap.add_argument("--serve-mode", default="w8a8",
                choices=["fp32", "w8a8", "w4a8"])
ap.add_argument("--replicas", type=int, default=1,
                help="independent NVE replicas integrated in one batch")
args = ap.parse_args()

data = sample_dataset(jax.random.PRNGKey(0), 128)

ckpt = os.path.join(pipe.ART, "ckpt_fp32.npz")
if os.path.exists(ckpt):
    cfg = so3.So3kratesConfig(**pipe.BASE, **pipe.METHODS["fp32"])
    params = pipe.load_params(ckpt)
    print("using pipeline checkpoint", ckpt)
else:
    cfg = so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2)
    params, _ = train(cfg, data, TrainConfig(epochs=30, warmup_epochs=0,
                                             batch_size=32, lr=5e-3))

# deployment step: fold the label standardization into the (linear)
# energy head, so the served model emits physical eV directly
e_scale = float(data["e_scale"])
params = {**params, "ro_w2": params["ro_w2"] * e_scale}

# --- serving engine + device-resident MD off the same quantized weights ----
engine = QuantizedEngine.from_config(
    cfg, params=params,
    serve=ServeConfig(mode=args.serve_mode, bucket_sizes=(32,),
                      max_batch=8))
mem = engine.memory_report()
print(f"serving mode={args.serve_mode} backend={engine.backend} "
      f"interpret={engine.interpret}: fp32 {mem['fp32_bytes']/1e3:.1f} KB -> "
      f"{mem['served_bytes']/1e3:.1f} KB ({mem['compression_x']}x)")

# skin 1.0 A: azobenzene's H atoms vibrate fast, and at 24 atoms the
# extra edge slots are cheap next to fewer rebuilds
REC_EVERY = 50
md = engine.md_engine(MDConfig(mode=args.serve_mode, dt_fs=args.dt_fs,
                               record_every=REC_EVERY, skin=1.0))
species = np.asarray(data["species"], np.int32)
eq = np.asarray(data["coords"][0], np.float32)
masses = np.asarray(pipe.MASSES, np.float32)
spec_b, co_b, mask_b = pad_replicas(species, eq, args.replicas)
masses_b = np.broadcast_to(masses, mask_b.shape)

state = md.init_state(jax.random.PRNGKey(7), spec_b, co_b, mask_b,
                      masses_b, temperature_K=300.0)
import time
t0 = time.time()
state, rec = md.run(state, spec_b, mask_b, masses_b, n_steps=args.steps)
wall = time.time() - t0
e = rec["e_tot"][:, 0]
# drift fit wants uniform spacing: drop any tail record
drift = energy_drift_rate(e[:args.steps // REC_EVERY], args.dt_fs,
                          REC_EVERY, species.shape[0])
blew_up = bool(~np.isfinite(e).all() or np.abs(e - e[0]).max() > 100.0)
print(f"\nNVE ({args.serve_mode}, device-resident) {args.steps} steps "
      f"@{args.dt_fs}fs x{args.replicas} replica(s): "
      f"drift {drift*1000:.3f} meV/atom/ps, blew_up={blew_up}, "
      f"wall {wall:.1f}s ({args.steps*args.replicas/wall:.0f} steps/s), "
      f"skin rebuilds {rec['n_rebuilds']} "
      f"(every ~{args.steps/max(rec['n_rebuilds'],1):.0f} steps)")

# --- deployment check: served forces track the fp32 model ------------------
frames = [Graph(species=species, coords=np.asarray(data["coords"][i]))
          for i in range(8)]
served = engine.infer_batch(frames)
f_ref = np.stack([np.asarray(so3.forces(params, cfg, data["species"],
                                        data["coords"][i]))
                  for i in range(8)])
f_srv = np.stack([r.forces for r in served])
fmae = float(np.abs(f_srv - f_ref).mean())
print(f"served vs fp32 forces on 8 test frames: MAE {fmae:.4f} (eV/A)")
diag = engine.lee_diagnostic(frames[:4], jax.random.PRNGKey(3),
                             n_rotations=2)
print(f"served-model LEE: mean {diag['lee_mean']:.3e} "
      f"max {diag['lee_max']:.3e}")
