"""Example: NVE molecular dynamics with a learned (and quantized) force
field — the paper's Fig. 3 experiment at reduced scale.

Uses the pipeline's trained checkpoints if present (artifacts/so3/), else
trains a quick FP32 model. Runs NVE and reports the energy drift rate.

Run:  PYTHONPATH=src python examples/md_stability.py [--steps 4000]
"""
import argparse
import os

import jax

from repro.data.synthetic_md import sample_dataset
from repro.models import so3krates as so3
from repro.training import pipeline as pipe
from repro.training.so3_trainer import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=4000)
args = ap.parse_args()

data = sample_dataset(jax.random.PRNGKey(0), 128)

ckpt = os.path.join(pipe.ART, "ckpt_fp32.npz")
if os.path.exists(ckpt):
    cfg = so3.So3kratesConfig(**pipe.BASE, **pipe.METHODS["fp32"])
    params = pipe.load_params(ckpt)
    print("using pipeline checkpoint", ckpt)
else:
    cfg = so3.So3kratesConfig(feat=32, vec_feat=8, n_layers=2)
    params, _ = train(cfg, data, TrainConfig(epochs=30, warmup_epochs=0,
                                             batch_size=32, lr=5e-3))

res = pipe.nve_eval(cfg, params, data, n_steps=args.steps, dt_fs=0.25)
print(f"NVE {args.steps} steps @0.25fs: drift "
      f"{res['drift_ev_per_atom_ps']*1000:.3f} meV/atom/ps, "
      f"blew_up={res['blew_up']}, wall {res['wall_s']:.1f}s")
