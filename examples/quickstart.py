"""Quickstart: the GAQ core in 60 lines.

Shows the paper's three ingredients on real tensors:
 1. MDDQ — magnitude-direction decoupled quantization of l=1 features,
    with its bounded-equivariance guarantee (Prop 3.4),
 2. Geometric STE — tangent-space gradients through the quantizer,
 3. robust cosine attention — bounded logits under low precision,
plus the W4A8 quantized matmul kernel path (ref oracle on CPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (MDDQConfig, covering_radius, lee, make_codebook,
                        mddq_fake_quant, random_rotation,
                        robust_attention_weights)
from repro.kernels import ops

key = jax.random.PRNGKey(0)

# --- 1. MDDQ ---------------------------------------------------------------
cfg = MDDQConfig(direction_bits=12)          # 4096-point spherical codebook
codebook = cfg.codebook()
delta = covering_radius(codebook, n_samples=50_000)
print(f"codebook: {codebook.shape[0]} points, covering radius "
      f"{delta:.4f} rad")

v = jax.random.normal(key, (1024, 3)) * 3.0   # a field of l=1 features
v_q = mddq_fake_quant(v, cfg, codebook)
ang = jnp.arccos(jnp.clip(jnp.sum(v * v_q, -1)
                          / (jnp.linalg.norm(v, axis=-1)
                             * jnp.linalg.norm(v_q, axis=-1)), -1, 1))
print(f"max angular error {float(ang.max()):.4f} rad <= delta ✓")

# approximate equivariance: Q(Rv) vs R Q(v), bounded by 2 sin(delta/2) |v|
R = random_rotation(jax.random.fold_in(key, 1))
err = jnp.linalg.norm(mddq_fake_quant(v @ R.T, cfg, codebook)
                      - mddq_fake_quant(v, cfg, codebook) @ R.T, axis=-1)
bound = 2 * 2 * jnp.sin(delta / 2) * jnp.linalg.norm(v, axis=-1)
print(f"equivariance error: max {float(err.max()):.4f}, "
      f"bound {float(bound.max()):.4f} ✓ ({float((err <= bound+1e-5).mean())*100:.0f}% within)")

# --- 2. Geometric STE: direction gradients are tangent to the sphere --------
from repro.core import geometric_ste_direction, quantize_direction

u = v / jnp.linalg.norm(v, axis=-1, keepdims=True)
target = jax.random.normal(jax.random.fold_in(key, 9), (3,))


def dir_loss(uu):
    q = geometric_ste_direction(uu, quantize_direction(uu, codebook))
    return jnp.sum(q @ target)


g = jax.grad(dir_loss)(u)
radial = jnp.abs(jnp.sum(g * u, -1)) / jnp.maximum(
    jnp.linalg.norm(g, axis=-1), 1e-9)
print(f"direction-gradient radial fraction via Geometric STE: "
      f"{float(radial.max()):.2e} (tangent to S^2 ✓, Prop III.1)")

# --- 3. robust attention: scale-invariant, bounded logits -------------------
q = jax.random.normal(jax.random.fold_in(key, 2), (4, 8, 32)) * 100.0
k = jax.random.normal(jax.random.fold_in(key, 3), (4, 8, 32)) * 0.01
w = robust_attention_weights(q, k, tau=10.0)
print(f"attention rows sum to {float(w.sum(-1).mean()):.4f}; outlier scales "
      f"neutralized (logits bounded by tau=10)")

# --- 4. W4A8 quantized matmul (kernel ref path) ------------------------------
x = jax.random.normal(jax.random.fold_in(key, 4), (64, 256))
wmat = jax.random.normal(jax.random.fold_in(key, 5), (256, 128))
w_packed, w_scale = ops.prepare_w4(wmat)
y = ops.matmul_w4a8(x, w_packed, w_scale)
rel = float(jnp.linalg.norm(y - x @ wmat) / jnp.linalg.norm(x @ wmat))
print(f"W4A8 matmul: weight bytes {w_packed.nbytes} vs fp32 {wmat.nbytes} "
      f"(8x), rel err {rel:.3f}")
print("quickstart OK")
